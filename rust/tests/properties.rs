//! Property-based tests on coordinator invariants.
//!
//! proptest is unavailable offline (DESIGN.md "Decisions & risks"); these
//! are randomized sweeps driven by the repo's own deterministic RNG — same
//! shape: generate many random instances, assert the invariant on each.

use grades::config::{EsConfig, GradesConfig, RepoConfig};
use grades::coordinator::classic_es::ClassicEs;
use grades::coordinator::flops::FlopsCounter;
use grades::coordinator::freeze::{FreezeReason, FreezeState};
use grades::coordinator::grades::GradesMonitor;
use grades::coordinator::lr::CosineSchedule;
use grades::coordinator::scheduler::StepPlan;
use grades::data;
use grades::data::batcher::{eval_batches, pack_rows, BatchIter};
use grades::data::corpus::{generate, GrammarGen};
use grades::data::vocab::{Vocab, EOS};
use grades::runtime::host_backend::HostBackend;
use grades::runtime::session::Session;
use grades::util::json;
use grades::util::rng::Rng;

fn grades_cfg(tau: f64, alpha: f64, patience: usize) -> GradesConfig {
    GradesConfig {
        metric: "l1_diff".into(),
        alpha,
        tau,
        tau_vision: f64::NAN,
        tau_language: f64::NAN,
        patience,
        unfreeze_factor: 0.0,
        granularity: "matrix".into(),
    }
}

/// Build a manifest-shaped stand-in via the corpus of component metadata.
fn manifest(n_layers: usize) -> grades::runtime::manifest::Manifest {
    // reuse the shape the monitor tests in-crate use: 7 components/layer
    use grades::runtime::manifest::{Component, FlopsInfo, Manifest};
    let kinds = ["q", "k", "v", "o", "gate", "up", "down"];
    let mut components = Vec::new();
    for l in 0..n_layers {
        for k in kinds {
            components.push(Component {
                idx: components.len(),
                name: format!("language.{l}.{k}"),
                layer: l,
                kind: k.to_string(),
                group: if matches!(k, "q" | "k" | "v" | "o") {
                    "attention".into()
                } else {
                    "mlp".into()
                },
                tower: "language".into(),
                n_params: 16,
                tensors: vec![format!("lang.{l}.{k}")],
            });
        }
    }
    let n = components.len();
    let mut per = std::collections::BTreeMap::new();
    for c in &components {
        per.insert(c.name.clone(), 10.0);
    }
    Manifest {
        name: "prop".into(),
        kind: "lm".into(),
        method: "fp".into(),
        optimizer: "adamw".into(),
        kernel_impl: "xla".into(),
        batch_size: 4,
        seq_len: 8,
        vocab_size: 256,
        n_patches: 0,
        patch_dim: 0,
        state_len: 64,
        metrics_len: 4 + 2 * n,
        ctrl_len: 4 + n,
        n_components: n,
        gdiff_offset: 4,
        gabs_offset: 4 + n,
        gvar_offset: None,
        ctrl_mask_offset: 4,
        components,
        params: vec![],
        n_params_total: 0,
        n_params_trainable: 0,
        flops: FlopsInfo {
            fwd_per_token: 100.0,
            bwd_dx_per_token: 100.0,
            per_component_fwd: per,
            attn_quadratic_per_token: 0.0,
            head_per_token: 0.0,
        },
        executables: Default::default(),
        variants: Default::default(),
    }
}

/// Random freeze/unfreeze stream driven through the GradES monitor in a
/// given granularity; after every observation the derived plan must be
/// sound (omitted ⊆ frozen) and exact (omitted == frozen while elision
/// is on), and the lattice lowering must stay a sound subset.
fn drive_plan_soundness(granularity: &str, seed: u64) {
    use grades::coordinator::scheduler::{StepPlanner, VariantDef, VariantLattice};
    let mut rng = Rng::new(seed);
    for trial in 0..30 {
        let m = manifest(1 + rng.below(3));
        let n = m.n_components;
        let mut cfg = grades_cfg(0.5, 0.0, rng.below(2));
        cfg.granularity = granularity.into();
        // half the trials exercise dynamic unfreezing on the gabs metric
        if rng.chance(0.5) {
            cfg.metric = "l1_abs".into();
            cfg.unfreeze_factor = 1.5;
        }
        let mut mon = GradesMonitor::new(&cfg, &m, 100).unwrap();
        let mut fs = FreezeState::new(n);
        // note: the *raw* planner (elision unconditionally on) — the
        // soundness property must hold even when frozen components can
        // unfreeze underneath it
        let mut planner = StepPlanner::new(&m, true);
        let attn = m.components_where(|c| c.group == "attention");
        let lattice = VariantLattice::new(vec![
            VariantDef { key: "train_step".into(), omit: vec![] },
            VariantDef { key: "train_step_attn_frozen".into(), omit: attn },
        ])
        .unwrap();
        for t in 1..=40 {
            let mut metrics = vec![0f32; m.metrics_len];
            for c in 0..n {
                let v = if rng.chance(0.5) { 0.1 } else { 2.0 };
                metrics[m.gdiff_offset + c] = v;
                metrics[m.gabs_offset + c] = v;
            }
            mon.observe(t, &m, &metrics, 1.0, &mut fs);
            let plan = planner.plan(t, &fs);
            assert!(
                plan.is_sound(&fs),
                "trial {trial} t={t} ({granularity}): plan omits an active component"
            );
            for c in 0..n {
                assert_eq!(
                    plan.omits(c),
                    fs.is_frozen(c),
                    "trial {trial} t={t}: plan is not exactly the frozen set"
                );
            }
            let lowered = lattice.lower(&plan);
            assert!(
                lowered.omit.iter().all(|&c| plan.omits(c)),
                "trial {trial} t={t}: lowering omitted an unplanned component"
            );
        }
    }
}

#[test]
fn prop_plan_soundness_matrix_granularity() {
    drive_plan_soundness("matrix", 0x9e1);
}

#[test]
fn prop_plan_soundness_layer_granularity() {
    drive_plan_soundness("layer", 0x9e2);
}

#[test]
fn prop_monitor_never_freezes_during_grace_period() {
    let mut rng = Rng::new(1);
    for trial in 0..50 {
        let m = manifest(1 + rng.below(4));
        let alpha = rng.f64();
        let total = 50 + rng.below(500);
        let mut mon = GradesMonitor::new(&grades_cfg(1e9, alpha, 0), &m, total).unwrap();
        let mut fs = FreezeState::new(m.n_components);
        let metrics = vec![0f32; m.metrics_len]; // all zero → below any τ
        let grace = mon.grace_steps();
        for t in 1..=grace {
            assert_eq!(
                mon.observe(t, &m, &metrics, 1.0, &mut fs),
                0,
                "trial {trial}: froze inside grace (t={t}, grace={grace})"
            );
        }
        if grace < total {
            assert!(mon.observe(grace + 1, &m, &metrics, 1.0, &mut fs) > 0);
        }
    }
}

#[test]
fn prop_frozen_set_is_monotone_without_unfreeze() {
    let mut rng = Rng::new(2);
    for _ in 0..30 {
        let m = manifest(2);
        let mut mon = GradesMonitor::new(&grades_cfg(rng.f64() * 5.0, 0.0, rng.below(3)), &m, 100).unwrap();
        let mut fs = FreezeState::new(m.n_components);
        let mut prev_frozen = 0;
        for t in 1..=60 {
            let mut metrics = vec![0f32; m.metrics_len];
            for c in 0..m.n_components {
                metrics[m.gdiff_offset + c] = (rng.f64() * 8.0) as f32;
            }
            mon.observe(t, &m, &metrics, 1.0, &mut fs);
            assert!(fs.n_frozen() >= prev_frozen, "frozen count decreased");
            prev_frozen = fs.n_frozen();
        }
        // every event metric was below τ at its freeze step
        for e in &fs.events {
            assert!(e.frozen);
            assert!(e.metric_value < mon.tau(e.component) + 1e-9);
        }
    }
}

#[test]
fn prop_tau_zero_never_freezes_anything() {
    // The metric is an L1 norm (≥ 0) and the test is a strict `< τ`, so
    // τ = 0 can never fire — before the grace period or after it.
    let mut rng = Rng::new(21);
    for _ in 0..30 {
        let m = manifest(1 + rng.below(3));
        let alpha = rng.f64() * 0.5;
        let mut mon = GradesMonitor::new(&grades_cfg(0.0, alpha, rng.below(3)), &m, 80).unwrap();
        let mut fs = FreezeState::new(m.n_components);
        for t in 1..=80 {
            let mut metrics = vec![0f32; m.metrics_len];
            for c in 0..m.n_components {
                // include exact zeros: 0 < 0 is still false
                metrics[m.gdiff_offset + c] =
                    if rng.chance(0.3) { 0.0 } else { (rng.f64() * 4.0) as f32 };
            }
            assert_eq!(mon.observe(t, &m, &metrics, 1.0, &mut fs), 0);
        }
        assert_eq!(fs.n_frozen(), 0, "tau=0 froze a component");
        assert!(fs.events.is_empty());
    }
}

#[test]
fn prop_tau_infinite_freezes_everything_at_first_eligible_step() {
    let mut rng = Rng::new(22);
    for _ in 0..30 {
        let m = manifest(1 + rng.below(3));
        let total = 20 + rng.below(60);
        let alpha = rng.f64() * 0.8;
        let mut mon = GradesMonitor::new(&grades_cfg(f64::INFINITY, alpha, 0), &m, total).unwrap();
        let mut fs = FreezeState::new(m.n_components);
        let first_eligible = mon.grace_steps() + 1;
        for t in 1..=first_eligible {
            let mut metrics = vec![0f32; m.metrics_len];
            for c in 0..m.n_components {
                metrics[m.gdiff_offset + c] = (rng.f64() * 1e6) as f32;
            }
            let newly = mon.observe(t, &m, &metrics, 1.0, &mut fs);
            if t <= mon.grace_steps() {
                assert_eq!(newly, 0, "froze before the grace period ended");
            } else {
                assert_eq!(newly, m.n_components, "τ=∞ must freeze everything at once");
            }
        }
        assert!(fs.all_frozen());
        assert!(fs.events.iter().all(|e| e.step == first_eligible));
        assert!(mon.should_terminate(&fs));
    }
}

/// Reference reimplementation of the freeze rule: recompute the
/// candidate set from scratch every step (the O(n²)-ish rescan the
/// monitor's reused bitmap replaced in PR 1). Mirrors Alg. 1 lines 8–11
/// plus the optional patience and layer-granularity extensions.
struct NaiveMonitor {
    grace: usize,
    tau: f64,
    patience: usize,
    layer_mode: bool,
    below: Vec<usize>,
    frozen: Vec<bool>,
    events: Vec<(usize, usize)>,
}

impl NaiveMonitor {
    fn observe(&mut self, t: usize, values: &[f64], layers: &[Vec<usize>]) {
        if t <= self.grace {
            return;
        }
        // fresh candidate scan, no carried bitmap
        let mut candidate = vec![false; values.len()];
        for c in 0..values.len() {
            if self.frozen[c] {
                continue;
            }
            if values[c] < self.tau {
                self.below[c] += 1;
                if self.below[c] > self.patience {
                    candidate[c] = true;
                }
            } else {
                self.below[c] = 0;
            }
        }
        if self.layer_mode {
            for group in layers {
                if group.iter().all(|&c| self.frozen[c] || candidate[c]) {
                    for &c in group {
                        if !self.frozen[c] {
                            self.frozen[c] = true;
                            self.events.push((t, c));
                        }
                    }
                }
            }
        } else {
            for (c, &ready) in candidate.iter().enumerate() {
                if ready {
                    self.frozen[c] = true;
                    self.events.push((t, c));
                }
            }
        }
    }
}

#[test]
fn prop_candidate_bitmap_matches_naive_rescan() {
    // The monitor's O(n) reused candidate bitmap must produce exactly
    // the freeze schedule of a from-scratch rescan, on random gradient
    // streams, in both matrix and layer granularity.
    let mut rng = Rng::new(23);
    for trial in 0..40 {
        let n_layers = 1 + rng.below(3);
        let m = manifest(n_layers);
        let tau = rng.f64() * 2.0;
        let patience = rng.below(4);
        let layer_mode = rng.chance(0.5);
        let mut cfg = grades_cfg(tau, 0.1, patience);
        if layer_mode {
            cfg.granularity = "layer".into();
        }
        let total = 60;
        let mut mon = GradesMonitor::new(&cfg, &m, total).unwrap();
        let mut fs = FreezeState::new(m.n_components);
        let layers: Vec<Vec<usize>> = (0..n_layers)
            .map(|l| m.components_where(|c| c.layer == l))
            .collect();
        let mut naive = NaiveMonitor {
            grace: mon.grace_steps(),
            tau,
            patience,
            layer_mode,
            below: vec![0; m.n_components],
            frozen: vec![false; m.n_components],
            events: Vec::new(),
        };
        for t in 1..=total {
            let mut metrics = vec![0f32; m.metrics_len];
            let mut values = vec![0f64; m.n_components];
            for c in 0..m.n_components {
                let v = rng.f64() * 3.0;
                metrics[m.gdiff_offset + c] = v as f32;
                values[c] = metrics[m.gdiff_offset + c] as f64; // post-f32 rounding
            }
            mon.observe(t, &m, &metrics, 1.0, &mut fs);
            naive.observe(t, &values, &layers);
            for c in 0..m.n_components {
                assert_eq!(
                    fs.is_frozen(c),
                    naive.frozen[c],
                    "trial {trial}: frozen sets diverge at step {t}, component {c}"
                );
            }
        }
        let got: Vec<(usize, usize)> = fs.events.iter().map(|e| (e.step, e.component)).collect();
        assert_eq!(got, naive.events, "trial {trial}: freeze schedules diverge");
    }
}

#[test]
fn prop_flops_monotone_decreasing_in_frozen_set() {
    let mut rng = Rng::new(3);
    for _ in 0..30 {
        let m = manifest(1 + rng.below(3));
        let mut fs = FreezeState::new(m.n_components);
        let mut order: Vec<usize> = (0..m.n_components).collect();
        rng.shuffle(&mut order);
        let mut prev = FlopsCounter::step_cost(&m, &fs);
        assert_eq!(prev, FlopsCounter::dense_step(&m));
        for c in order {
            fs.freeze(c, 1, FreezeReason::Converged, 0.0);
            let cur = FlopsCounter::step_cost(&m, &fs);
            assert!(cur < prev, "cost must strictly drop per freeze");
            prev = cur;
        }
        // floor: fwd + dX always remain (gradient-flow preservation)
        let tokens = (m.batch_size * m.seq_len) as f64;
        assert!((prev - tokens * 200.0).abs() < 1e-9);
    }
}

#[test]
fn prop_classic_es_stops_iff_patience_exceeded() {
    let mut rng = Rng::new(4);
    for _ in 0..50 {
        let patience = 1 + rng.below(5);
        let cfg = EsConfig { check_interval_frac: 0.05, patience, min_delta: 0.01 };
        let mut es = ClassicEs::new(&cfg, 100);
        let mut bad_streak = 0usize;
        let mut best = f64::INFINITY;
        for _ in 0..40 {
            let loss = rng.f64();
            let stop = es.record(loss, 0.0);
            if loss < best - cfg.min_delta {
                best = loss;
                bad_streak = 0;
            } else {
                bad_streak += 1;
            }
            assert_eq!(stop, bad_streak >= patience);
            if stop {
                break;
            }
        }
    }
}

#[test]
fn prop_cosine_schedule_bounded_and_decaying() {
    let mut rng = Rng::new(5);
    for _ in 0..40 {
        let base = rng.f64() * 0.1 + 1e-5;
        let total = 20 + rng.below(1000);
        let s = CosineSchedule::new(base, rng.f64() * 0.2, total);
        for t in 1..=total {
            let lr = s.lr(t);
            assert!((0.0..=base * (1.0 + 1e-9)).contains(&lr), "lr out of range");
        }
        assert!(s.lr(total) <= s.lr(s.warmup_steps.max(1)));
    }
}

#[test]
fn prop_packing_preserves_next_token_alignment() {
    let mut rng = Rng::new(6);
    let v = Vocab::build(256).unwrap();
    for trial in 0..20 {
        let n = 5 + rng.below(60);
        let t = 16 + rng.below(100);
        let sentences = generate(&v, trial as u64, n);
        let rows = pack_rows(&sentences, t);
        for (tok, tgt) in &rows {
            assert_eq!(tok.len(), t);
            assert_eq!(tgt.len(), t);
            for i in 0..t - 1 {
                if tgt[i] >= 0 && tgt[i + 1] >= 0 {
                    assert_eq!(tok[i + 1], tgt[i], "alignment broken");
                }
            }
            // all ids in range
            assert!(tok.iter().all(|&x| x >= 0 && (x as usize) < v.vocab_size));
            assert!(tgt.iter().all(|&x| x >= -1 && (x as usize as i64) < v.vocab_size as i64 || x == -1));
        }
    }
}

#[test]
fn prop_batch_iter_yields_constant_shape_and_covers_rows() {
    let mut rng = Rng::new(7);
    let v = Vocab::build(256).unwrap();
    for trial in 0..10 {
        let sentences = generate(&v, 100 + trial as u64, 20 + rng.below(40));
        let rows = pack_rows(&sentences, 32);
        let n = rows.len();
        let bsz = 1 + rng.below(6);
        let mut it = BatchIter::new(rows, bsz, trial as u64);
        let mut seen_epoch = it.epoch;
        for _ in 0..(3 * n / bsz + 2) {
            let b = it.next_batch();
            assert_eq!(b.tokens.len(), bsz * 32);
            assert_eq!(b.targets.len(), bsz * 32);
            assert!(it.epoch >= seen_epoch);
            seen_epoch = it.epoch;
        }
        assert!(it.epoch >= 1, "must have cycled at least one epoch");
    }
}

#[test]
fn prop_eval_batches_mask_padding_rows() {
    let mut rng = Rng::new(8);
    for _ in 0..20 {
        let nrows = 1 + rng.below(20);
        let bsz = 1 + rng.below(8);
        let t = 4 + rng.below(12);
        let rows: Vec<_> = (0..nrows).map(|i| (vec![i as i32; t], vec![i as i32; t])).collect();
        let batches = eval_batches(&rows, bsz, t);
        assert_eq!(batches.len(), nrows.div_ceil(bsz));
        let total_valid: usize = batches
            .iter()
            .flat_map(|b| b.targets.iter())
            .filter(|&&x| x >= 0)
            .count();
        assert_eq!(total_valid, nrows * t, "padding must be fully masked");
    }
}

#[test]
fn prop_corruptions_always_produce_invalid_variant() {
    let v = Vocab::build(512).unwrap();
    let g = GrammarGen::new(&v);
    let mut rng = Rng::new(9);
    for _ in 0..200 {
        let s = g.sentence(&mut rng);
        for rule in ["det", "adj", "verb_obj", "det2", "swap", "adv"] {
            let c = g.corrupt(&mut rng, &s, rule);
            assert_ne!(c.ids, s.ids, "corruption {rule} was a no-op");
            assert_eq!(c.ids.len(), s.ids.len());
            assert_eq!(*c.ids.last().unwrap(), EOS);
        }
    }
}

#[test]
fn prop_kernels_bitwise_identical_across_simd_levels_and_threads() {
    // The host-kernel determinism contract, stated once and enforced
    // forever: on randomized shapes, every available SIMD level and
    // every thread count in {1, 2, 4} produces exactly the bits of the
    // scalar lane-emulating fallback on 1 thread — for all three matmul
    // forms and all four L1/dot reductions.
    use grades::runtime::host_kernels as hk;
    let levels = hk::available_levels();
    let mut rng = Rng::new(0xce11);
    for trial in 0..25 {
        let (m, k, n) = (1 + rng.below(24), 1 + rng.below(24), 1 + rng.below(24));
        let a: Vec<f32> = (0..m * k).map(|_| rng.gauss() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gauss() as f32).collect();
        let c: Vec<f32> = (0..m * k).map(|_| rng.gauss() as f32).collect();
        let base_mm = hk::matmul_with(hk::SimdLevel::Scalar, 1, &a, &b, m, k, n);
        let base_tn = hk::matmul_tn_with(hk::SimdLevel::Scalar, 1, &a, &c, m, k, k);
        let base_nt = hk::matmul_nt_with(hk::SimdLevel::Scalar, 1, &a, &c, m, k, m);
        let base_dot = hk::dot8_with(hk::SimdLevel::Scalar, &a, &c);
        let base_abs = hk::abs_sum8_with(hk::SimdLevel::Scalar, &a);
        let base_ad = hk::abs_diff_sum8_with(hk::SimdLevel::Scalar, &a, &c);
        let scale: Vec<f32> = (0..k).map(|_| rng.gauss() as f32).collect();
        let base_d3 = hk::dot3_8_with(hk::SimdLevel::Scalar, &a[..k], &scale, &c[..k]);
        for &level in &levels {
            for threads in [1usize, 2, 4] {
                let ctx = format!("trial {trial} {level:?}/{threads}t ({m}x{k}x{n})");
                let mm = hk::matmul_with(level, threads, &a, &b, m, k, n);
                assert!(
                    mm.iter().zip(&base_mm).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{ctx}: matmul diverged from scalar/1t"
                );
                let tn = hk::matmul_tn_with(level, threads, &a, &c, m, k, k);
                assert!(
                    tn.iter().zip(&base_tn).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{ctx}: matmul_tn diverged from scalar/1t"
                );
                let nt = hk::matmul_nt_with(level, threads, &a, &c, m, k, m);
                assert!(
                    nt.iter().zip(&base_nt).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{ctx}: matmul_nt diverged from scalar/1t"
                );
                assert_eq!(hk::dot8_with(level, &a, &c).to_bits(), base_dot.to_bits(), "{ctx}: dot8");
                assert_eq!(
                    hk::abs_sum8_with(level, &a).to_bits(),
                    base_abs.to_bits(),
                    "{ctx}: abs_sum8"
                );
                assert_eq!(
                    hk::abs_diff_sum8_with(level, &a, &c).to_bits(),
                    base_ad.to_bits(),
                    "{ctx}: abs_diff_sum8"
                );
                assert_eq!(
                    hk::dot3_8_with(level, &a[..k], &scale, &c[..k]).to_bits(),
                    base_d3.to_bits(),
                    "{ctx}: dot3_8"
                );
            }
        }
        // anchor: the lane-split result is a real matmul (vs naive f64)
        let mut naive = vec![0f64; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    naive[i * n + j] += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
            }
        }
        for (x, y) in base_mm.iter().zip(&naive) {
            let rel = (*x as f64 - y).abs() / y.abs().max(1e-6);
            assert!(rel < 1e-4, "trial {trial}: lane-split matmul drifted from naive f64");
        }
    }
}

#[test]
fn prop_fused_attention_and_elementwise_kernels_bitwise_identical() {
    // The PR-10 extension of the contract above to the second kernel
    // family: row-blocked fused attention (forward + backward, causal
    // and non-causal) and the softmax/SwiGLU elementwise kernels, swept
    // over SIMD levels × threads {1, 2, 4} × arena on/off — everything
    // bitwise equal to the scalar/1-thread/arena-on result, anchored
    // against a naive f64 attention and a naive f64 silu·up.
    use grades::runtime::host_arena::{self, buf_raw, buf_zeroed};
    use grades::runtime::host_kernels as hk;
    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }
    let levels = hk::available_levels();
    let mut rng = Rng::new(0xa77e);
    for trial in 0..8 {
        let (b, t, h, hd) =
            (1 + rng.below(2), 1 + rng.below(9), 1 + rng.below(3), 1 + rng.below(8));
        let d = h * hd;
        let q: Vec<f32> = (0..b * t * d).map(|_| rng.gauss() as f32).collect();
        let k: Vec<f32> = (0..b * t * d).map(|_| rng.gauss() as f32).collect();
        let v: Vec<f32> = (0..b * t * d).map(|_| rng.gauss() as f32).collect();
        let dctx: Vec<f32> = (0..b * t * d).map(|_| rng.gauss() as f32).collect();
        for causal in [false, true] {
            // one full fwd+bwd at an explicit level/thread/arena choice,
            // with every buffer carved so arena-on runs exercise recycled
            // (stale-content) storage
            let run = |level: hk::SimdLevel, threads: usize, arena: bool| {
                host_arena::set_arena_override(Some(arena));
                let mut ctx = buf_raw(b * h * t * hd);
                let mut stats = buf_raw(b * h * 2 * t);
                let mut scratch = buf_raw(b * h * t);
                hk::fused_attention_fwd_with(
                    level, threads, &q, &k, &v, b, t, h, hd, causal, &mut ctx, &mut stats,
                    &mut scratch,
                );
                let mut gathered = buf_raw(b * t * d);
                hk::gather_heads(&ctx, b, t, h, hd, &mut gathered);
                let mut dq = buf_zeroed(b * h * t * hd);
                let mut dk = buf_zeroed(b * h * t * hd);
                let mut dv = buf_zeroed(b * h * t * hd);
                let mut bscr = buf_raw(b * h * 2 * t);
                hk::fused_attention_bwd_with(
                    level, threads, &q, &k, &v, &stats, &dctx, b, t, h, hd, causal, &mut dq,
                    &mut dk, &mut dv, &mut bscr,
                );
                host_arena::set_arena_override(None);
                (gathered.to_vec(), stats.to_vec(), dq.to_vec(), dk.to_vec(), dv.to_vec())
            };
            let base = run(hk::SimdLevel::Scalar, 1, true);
            // anchor: the fused forward is a real attention (vs naive f64)
            let mut naive = vec![0f64; b * t * d];
            for bi in 0..b {
                for hh in 0..h {
                    for t1 in 0..t {
                        let limit = if causal { t1 + 1 } else { t };
                        let mut scores = vec![0f64; limit];
                        for (t2, s) in scores.iter_mut().enumerate() {
                            for di in 0..hd {
                                *s += q[(bi * t + t1) * d + hh * hd + di] as f64
                                    * k[(bi * t + t2) * d + hh * hd + di] as f64;
                            }
                            *s /= (hd as f64).sqrt();
                        }
                        let mx = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                        let sum: f64 = scores.iter().map(|s| (s - mx).exp()).sum();
                        for (t2, s) in scores.iter().enumerate() {
                            let p = (s - mx).exp() / sum;
                            for di in 0..hd {
                                naive[(bi * t + t1) * d + hh * hd + di] +=
                                    p * v[(bi * t + t2) * d + hh * hd + di] as f64;
                            }
                        }
                    }
                }
            }
            for (x, y) in base.0.iter().zip(&naive) {
                assert!(
                    (*x as f64 - y).abs() < 1e-4 * y.abs().max(1.0),
                    "trial {trial} causal={causal}: fused attention drifted from naive f64"
                );
            }
            for &level in &levels {
                for threads in [1usize, 2, 4] {
                    for arena in [true, false] {
                        let got = run(level, threads, arena);
                        let ctx = format!(
                            "trial {trial} {level:?}/{threads}t arena={arena} causal={causal} \
                             (b={b} t={t} h={h} hd={hd})"
                        );
                        assert!(bits_eq(&got.0, &base.0), "{ctx}: ctx diverged");
                        assert!(bits_eq(&got.1, &base.1), "{ctx}: softmax stats diverged");
                        assert!(bits_eq(&got.2, &base.2), "{ctx}: dq diverged");
                        assert!(bits_eq(&got.3, &base.3), "{ctx}: dk diverged");
                        assert!(bits_eq(&got.4, &base.4), "{ctx}: dv diverged");
                    }
                }
            }
        }
        // elementwise family: SwiGLU fwd+bwd and the vexp-backed softmax
        // are single-op f32 math — bitwise across levels by construction,
        // pinned here anyway
        let n = 1 + rng.below(70);
        let gate: Vec<f32> = (0..n).map(|_| (rng.gauss() * 2.0) as f32).collect();
        let up: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
        let dact: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
        let swig = |level: hk::SimdLevel| {
            let (mut sig, mut act) = (vec![0f32; n], vec![0f32; n]);
            hk::swiglu_fwd_with(level, &gate, &up, &mut sig, &mut act);
            let (mut dgp, mut dup) = (vec![0f32; n], vec![0f32; n]);
            hk::swiglu_bwd(&dact, &gate, &up, &sig, &mut dgp, &mut dup);
            (sig, act, dgp, dup)
        };
        let sbase = swig(hk::SimdLevel::Scalar);
        for i in 0..n {
            let z = gate[i] as f64;
            let want = z / (1.0 + (-z).exp()) * up[i] as f64;
            assert!(
                (sbase.1[i] as f64 - want).abs() < 1e-5 * want.abs().max(1.0),
                "trial {trial}: swiglu forward drifted from f64 silu·up"
            );
        }
        let row: Vec<f32> = (0..1 + rng.below(40)).map(|_| (rng.gauss() * 3.0) as f32).collect();
        let mut rbase = row.clone();
        let stats_base = hk::softmax_row_with(hk::SimdLevel::Scalar, &mut rbase);
        let psum: f64 = rbase.iter().map(|&x| x as f64).sum();
        assert!((psum - 1.0).abs() < 1e-4, "trial {trial}: softmax row does not sum to 1");
        for &level in &levels {
            let got = swig(level);
            assert!(bits_eq(&got.0, &sbase.0), "trial {trial} {level:?}: sigmoid diverged");
            assert!(bits_eq(&got.1, &sbase.1), "trial {trial} {level:?}: swiglu act diverged");
            assert!(bits_eq(&got.2, &sbase.2), "trial {trial} {level:?}: d_gate diverged");
            assert!(bits_eq(&got.3, &sbase.3), "trial {trial} {level:?}: d_up diverged");
            let mut r = row.clone();
            let st = hk::softmax_row_with(level, &mut r);
            assert_eq!(st.0.to_bits(), stats_base.0.to_bits(), "{level:?}: softmax max");
            assert_eq!(st.1.to_bits(), stats_base.1.to_bits(), "{level:?}: softmax inv");
            assert!(bits_eq(&r, &rbase), "trial {trial} {level:?}: softmax probs diverged");
        }
    }
}

#[test]
fn prop_merged_weight_eval_matches_f64_adapter_fold() {
    // lora.py merge semantics as a property: on *random* adapters (the
    // init puts B at 0, which would make the fold a no-op) the LoRA
    // engine's merged-weight eval equals a full-parameter engine
    // evaluating weights folded independently in f64 — the adapter form
    // x@W + s·(x@A)@B collapses into one matrix without moving the loss
    // beyond the f32 rounding of the fold itself.
    let mut rng = Rng::new(0x10a);
    for trial in 0..5 {
        let mut cfg = RepoConfig::by_name("lm-tiny-lora").unwrap();
        cfg.train.lora_rank = 1 + rng.below(6);
        cfg.train.lora_alpha = (1 + rng.below(12)) as f64;
        let mut fp_cfg = RepoConfig::by_name("lm-tiny-lora").unwrap();
        fp_cfg.train.method = "fp".into();
        let lb = HostBackend::for_config(&cfg).unwrap();
        let fb = HostBackend::for_config(&fp_cfg).unwrap();
        let (ml, mf) = (lb.manifest(), fb.manifest());
        // the monitored component grids coincide, so the metric
        // prefixes — and with them the base-weight offsets — do too
        assert_eq!(ml.metrics_len, mf.metrics_len);
        assert_eq!(ml.n_components, mf.n_components);

        let mut ls = Session::new(&lb);
        ls.init(100 + trial as i32).unwrap();
        let mut host_l = ls.state_to_host().unwrap();
        for p in &ml.params {
            if p.name.ends_with(".lora_a") || p.name.ends_with(".lora_b") {
                for i in 0..p.size() {
                    host_l[p.offset + i] = (rng.gauss() * 0.2) as f32;
                }
            }
        }
        ls.state_from_host(&host_l).unwrap();

        // fp state: copy every base tensor, then fold the adapters in f64
        let mut host_f = vec![0f32; mf.state_len];
        let scale = cfg.train.lora_alpha / cfg.train.lora_rank as f64;
        for pf in &mf.params {
            let pl = ml.param(&pf.name).unwrap();
            assert_eq!(
                (pl.offset, &pl.shape),
                (pf.offset, &pf.shape),
                "base layouts diverge at {}",
                pf.name
            );
            host_f[pf.offset..pf.offset + pf.size()]
                .copy_from_slice(&host_l[pl.offset..pl.offset + pl.size()]);
            let (Some(pa), Some(pb)) = (
                ml.param(&format!("{}.lora_a", pf.name)),
                ml.param(&format!("{}.lora_b", pf.name)),
            ) else {
                continue;
            };
            let (dout, r) = (pf.shape[1], pa.shape[1]);
            for i in 0..pf.shape[0] {
                for j in 0..dout {
                    let mut acc = 0f64;
                    for k in 0..r {
                        acc += host_l[pa.offset + i * r + k] as f64
                            * host_l[pb.offset + k * dout + j] as f64;
                    }
                    let w = host_l[pl.offset + i * dout + j] as f64 + scale * acc;
                    host_f[pf.offset + i * dout + j] = w as f32;
                }
            }
        }
        let mut fsess = Session::new(&fb);
        fsess.state_from_host(&host_f).unwrap();

        let ds = data::build_lm(&cfg, ml).unwrap();
        for b in ds.val.iter().take(2) {
            let (la, ca) = ls.eval_batch(b).unwrap();
            let (lf, cf) = fsess.eval_batch(b).unwrap();
            assert_eq!(ca, cf, "trial {trial}: token counts diverge");
            let rel = (la - lf).abs() / la.abs().max(lf.abs()).max(1e-8);
            assert!(
                rel < 2e-3,
                "trial {trial} (r={}, α={}): merged eval {la} vs f64 fold {lf}",
                cfg.train.lora_rank,
                cfg.train.lora_alpha
            );
        }
    }
}

#[test]
fn prop_plan_elision_bitwise_on_random_freeze_streams() {
    // The per-step elision contract on the new engine families: for
    // *random* (even non-monotone, i.e. unfreezing) omitted sets, a
    // plan that skips frozen components' backward work must reproduce
    // the dense graph under the same ctrl mask bit for bit — params,
    // optimizer slots, prev-grads, and the metric prefix alike.
    let mut rng = Rng::new(0xe115);
    for config in ["lm-tiny-lora", "vlm-tiny-fp"] {
        let cfg = RepoConfig::by_name(config).unwrap();
        let be = HostBackend::for_config(&cfg).unwrap();
        let m = be.manifest();
        let n = m.n_components;
        let batches: Vec<_> = if m.is_vlm() {
            data::build_vlm(&cfg, m).unwrap().train
        } else {
            let mut ds = data::build_lm(&cfg, m).unwrap();
            (0..6).map(|_| ds.train.next_batch()).collect()
        };
        let mut planned = Session::new(&be);
        planned.init(5).unwrap();
        let mut dense = Session::new(&be);
        dense.init(5).unwrap();
        for t in 0..5usize {
            let omitted: Vec<usize> = (0..n).filter(|_| rng.chance(0.4)).collect();
            let mut ctrl = vec![0f32; m.ctrl_len];
            ctrl[0] = 1.0;
            ctrl[1] = 2e-3;
            ctrl[2] = 1.0;
            for c in 0..n {
                ctrl[m.ctrl_mask_offset + c] = if omitted.contains(&c) { 0.0 } else { 1.0 };
            }
            let b = &batches[t % batches.len()];
            planned.train_step(b, &ctrl, &StepPlan::omitting(n, &omitted)).unwrap();
            dense.train_step(b, &ctrl, &StepPlan::all_active(n)).unwrap();
            let sp = planned.state_to_host().unwrap();
            let sd = dense.state_to_host().unwrap();
            let diverged = sp.iter().zip(&sd).position(|(a, b)| a.to_bits() != b.to_bits());
            assert!(
                diverged.is_none(),
                "{config}: step {t} ({} omitted) diverges at state[{}]",
                omitted.len(),
                diverged.unwrap()
            );
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::new(10);
    fn random_json(rng: &mut Rng, depth: usize) -> json::Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.chance(0.5)),
            2 => json::Json::Num((rng.f64() * 1e6).round()),
            3 => json::Json::Str(format!("s{}-\"x\"\n", rng.below(1000))),
            4 => json::Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => json::Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for _ in 0..100 {
        let v = random_json(&mut rng, 0);
        let text = json::write(&v);
        let back = json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(v, back);
    }
}
