//! Host-side tests for the experiment scheduler — no PJRT artifacts
//! needed, so these always run under tier-1 `cargo test`.
//!
//! The executor is generic over [`JobRunner`], so a mock runner exercises
//! the scheduling properties the device runner relies on: dependency
//! ordering, `--jobs 1` vs `--jobs N` result equality, resume from a run
//! manifest, and worker-panic isolation. (The with-artifacts half —
//! `--jobs 1` vs `--jobs 4` producing identical table cells through real
//! training — rides on the same determinism argument: each job's
//! trajectory depends only on its spec, which these tests pin down.)

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use grades::coordinator::flops::FlopsCounter;
use grades::coordinator::freeze::FreezeState;
use grades::coordinator::metrics::MetricsLog;
use grades::coordinator::trainer::{StopCause, StoppingMethod, TrainOutcome};
use grades::coordinator::warmstart::BaseCheckpoint;
use grades::runtime::backend::BackendChoice;
use grades::exp::plan::{EvalKind, JobGraph, JobKind, JobSpec};
use grades::exp::scheduler::{
    execute, job_settings, EvalPayload, JobRunner, JobStatus, JobSummary, RetryPolicy,
    RunManifest, RunnerOutput, SchedulerOptions,
};
use grades::exp::JobResult;

/// Deterministic fake accuracy per job id (so result-set comparisons are
/// meaningful across executions and worker counts).
fn fake_acc(id: &str) -> f64 {
    id.bytes().map(|b| b as f64).sum::<f64>() % 100.0
}

fn fake_result(spec: &JobSpec) -> JobResult {
    JobResult {
        config: spec.config.clone(),
        method: spec.method,
        outcome: TrainOutcome {
            steps_run: 10,
            stop_cause: StopCause::BudgetExhausted,
            wall_secs: 1.0,
            validation_secs: 0.0,
            monitor_secs: 0.0,
            flops: FlopsCounter::default(),
            log: MetricsLog::default(),
            freeze: FreezeState::new(4),
            final_val_loss: 2.0,
            variant_swap_step: None,
            plan: Default::default(),
            timings: Default::default(),
            async_eval: Default::default(),
        },
        accuracies: vec![("Suite".to_string(), fake_acc(&spec.id)), ("Avg.".to_string(), fake_acc(&spec.id))],
    }
}

fn fake_summary(spec: &JobSpec, r: &JobResult) -> JobSummary {
    JobSummary {
        id: spec.id.clone(),
        config: r.config.clone(),
        // matches the default SchedulerOptions fingerprint ("" + the
        // auto-resolved backend — the same call execute() makes)
        settings: job_settings(spec, "", BackendChoice::Auto),
        backend: BackendChoice::Auto.resolve(&spec.config).label().to_string(),
        method: r.method.label().to_string(),
        steps_run: r.outcome.steps_run,
        stop_cause: "budget".to_string(),
        wall_secs: r.outcome.wall_secs,
        validation_secs: 0.0,
        monitor_secs: 0.0,
        final_val_loss: 2.0,
        variant_swap_step: None,
        flops_spent: 0.0,
        flops_realized: 0.0,
        flops_dense: 0.0,
        flops_validation: 0.0,
        flops_steps: r.outcome.steps_run,
        n_components: 4,
        frozen: Vec::new(),
        accuracies: r.accuracies.clone(),
        frozen_series: Vec::new(),
        tower_gabs: None,
        val_checks: 0,
        attempts: 1,
    }
}

/// Artifact-free runner: records start order, panics/fails on demand,
/// hands out fake checkpoints and deterministic fake results.
#[derive(Default)]
struct MockRunner {
    log: Mutex<Vec<String>>,
    panic_on: HashSet<String>,
    fail_on: HashSet<String>,
    /// id → number of *remaining* transient failures before it succeeds.
    flaky: Mutex<HashMap<String, usize>>,
}

impl MockRunner {
    fn started(&self) -> Vec<String> {
        self.log.lock().unwrap().clone()
    }
}

impl JobRunner for MockRunner {
    fn run(
        &self,
        spec: &JobSpec,
        warm: Option<Arc<BaseCheckpoint>>,
        eval_src: Option<Arc<EvalPayload>>,
    ) -> Result<RunnerOutput> {
        self.log.lock().unwrap().push(spec.id.clone());
        if self.panic_on.contains(&spec.id) {
            panic!("mock panic in {}", spec.id);
        }
        if self.fail_on.contains(&spec.id) {
            bail!("mock failure in {}", spec.id);
        }
        if let Some(left) = self.flaky.lock().unwrap().get_mut(&spec.id) {
            if *left > 0 {
                *left -= 1;
                bail!("mock transient failure in {}", spec.id);
            }
        }
        if spec.warm_from.is_some() && warm.is_none() {
            bail!("{}: warm checkpoint was not delivered", spec.id);
        }
        match spec.kind {
            JobKind::Pretrain => Ok(RunnerOutput {
                result: None,
                summary: None,
                checkpoint: Some(Arc::new(BaseCheckpoint {
                    params: Default::default(),
                    source: spec.id.clone(),
                })),
                eval_payload: None,
            }),
            JobKind::Train => {
                let result = fake_result(spec);
                let summary = spec.persist.then(|| fake_summary(spec, &result));
                // The weights an eval job will score, as plain host data.
                let eval_payload = spec.export_state.then(|| {
                    Arc::new(EvalPayload {
                        config: spec.config.clone(),
                        state: vec![fake_acc(&spec.id) as f32; 4],
                        step: 10,
                    })
                });
                Ok(RunnerOutput { result: Some(result), summary, checkpoint: None, eval_payload })
            }
            JobKind::Eval => {
                let payload = match eval_src {
                    Some(p) => p,
                    None => bail!("{}: eval payload was not delivered", spec.id),
                };
                if payload.config != spec.config {
                    bail!("{}: payload config mismatch", spec.id);
                }
                // Score = a function of the delivered weights, so the
                // test can assert the payload really flowed through.
                let mut result = fake_result(spec);
                let acc = payload.state[0] as f64;
                result.accuracies = vec![("Suite".into(), acc), ("Avg.".into(), acc)];
                Ok(RunnerOutput { result: Some(result), summary: None, checkpoint: None, eval_payload: None })
            }
        }
    }
}

fn train(id: &str) -> JobSpec {
    JobSpec::train(id, "fake-cfg", StoppingMethod::GradEs, EvalKind::None)
}

/// pretrain → 4 dependents, plus an independent pretrain → 2 dependents.
fn two_family_graph() -> JobGraph {
    let mut g = JobGraph::new();
    let pre_a = g.add(JobSpec::pretrain("pre-a", "fake-cfg")).unwrap();
    for i in 0..4 {
        g.add(train(&format!("a{i}")).warm(pre_a)).unwrap();
    }
    let pre_b = g.add(JobSpec::pretrain("pre-b", "fake-cfg")).unwrap();
    for i in 0..2 {
        g.add(train(&format!("b{i}")).warm(pre_b)).unwrap();
    }
    g
}

fn opts(jobs: usize) -> SchedulerOptions {
    SchedulerOptions { jobs, ..Default::default() }
}

/// Map of job id → final "Avg." accuracy for every Done-with-result job.
fn result_set(graph: &JobGraph, statuses: &[JobStatus]) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for (i, s) in statuses.iter().enumerate() {
        if let JobStatus::Done { result: Some(r), .. } = s {
            out.insert(graph.get(i).id.clone(), r.accuracies.last().unwrap().1);
        }
    }
    out
}

#[test]
fn dependencies_run_before_dependents_concurrently() {
    let g = two_family_graph();
    for jobs in [2, 4, 8] {
        let runner = MockRunner::default();
        let report = execute(&g, &opts(jobs), &runner).unwrap();
        report.require_ok(&g).unwrap();
        let order = runner.started();
        assert_eq!(order.len(), g.len(), "every job ran exactly once");
        let pos = |id: &str| order.iter().position(|x| x == id).unwrap();
        for i in 0..4 {
            assert!(pos("pre-a") < pos(&format!("a{i}")), "pretrain precedes a{i}");
        }
        for i in 0..2 {
            assert!(pos("pre-b") < pos(&format!("b{i}")), "pretrain precedes b{i}");
        }
    }
}

#[test]
fn jobs_1_and_jobs_n_produce_identical_result_sets() {
    let g = two_family_graph();
    let seq_runner = MockRunner::default();
    let seq = execute(&g, &opts(1), &seq_runner).unwrap();
    // sequential execution is strict plan order
    assert_eq!(
        seq_runner.started(),
        g.jobs.iter().map(|j| j.id.clone()).collect::<Vec<_>>()
    );
    let conc = execute(&g, &opts(4), &MockRunner::default()).unwrap();
    assert_eq!(result_set(&g, &seq.statuses), result_set(&g, &conc.statuses));
}

#[test]
fn resume_skips_completed_jobs() {
    let dir = std::env::temp_dir().join("grades_sched_resume_test");
    std::fs::remove_dir_all(&dir).ok();
    let manifest = dir.join("run_manifest.json");
    let sopts = SchedulerOptions {
        jobs: 1,
        manifest_path: Some(manifest.clone()),
        ..Default::default()
    };
    let g = two_family_graph();

    // First run executes everything and persists the train jobs.
    let first = MockRunner::default();
    execute(&g, &sopts, &first).unwrap().require_ok(&g).unwrap();
    assert_eq!(first.started().len(), g.len());
    assert!(manifest.exists());

    // Second run: all train jobs resume from the manifest, and the
    // pretrains are elided because every dependent is already done.
    let second = MockRunner::default();
    let report = execute(&g, &sopts, &second).unwrap();
    report.require_ok(&g).unwrap();
    assert!(second.started().is_empty(), "nothing re-ran: {:?}", second.started());
    let (ran, resumed, failed, skipped) = report.counts();
    assert_eq!((ran, resumed, failed, skipped), (0, g.len(), 0, 0));
    // resumed results still render table cells
    assert_eq!(result_set(&g, &report.statuses).len(), g.len() - 2);

    // Simulate a killed grid: drop one completed cell from the manifest.
    let mut m = RunManifest::load(&manifest);
    assert!(m.jobs.remove("a2").is_some());
    m.save(&manifest).unwrap();
    let third = MockRunner::default();
    execute(&g, &sopts, &third).unwrap().require_ok(&g).unwrap();
    // only the missing cell re-runs, plus its (cache-backed) pretrain
    assert_eq!(third.started(), vec!["pre-a".to_string(), "a2".to_string()]);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_rejects_entries_recorded_under_different_settings() {
    let dir = std::env::temp_dir().join("grades_sched_settings_test");
    std::fs::remove_dir_all(&dir).ok();
    let manifest = dir.join("run_manifest.json");
    let g = two_family_graph();
    let sopts = SchedulerOptions {
        jobs: 1,
        manifest_path: Some(manifest.clone()),
        ..Default::default()
    };
    execute(&g, &sopts, &MockRunner::default()).unwrap().require_ok(&g).unwrap();

    // Same graph, different run-wide settings (e.g. a full run after
    // --quick): nothing may resume from the quick-mode cells.
    let quickless = SchedulerOptions { settings: "steps_override=None".to_string(), ..sopts };
    let runner = MockRunner::default();
    execute(&g, &quickless, &runner).unwrap();
    assert_eq!(runner.started().len(), g.len(), "mismatched settings must re-run all jobs");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fresh_mode_preserves_other_targets_manifest_entries() {
    let dir = std::env::temp_dir().join("grades_sched_preserve_test");
    std::fs::remove_dir_all(&dir).ok();
    let manifest = dir.join("run_manifest.json");
    // Another repro target's completed cell already lives in the file.
    let other_spec = train("other-target/cell");
    let other = fake_summary(&other_spec, &fake_result(&other_spec));
    let mut m = RunManifest::default();
    m.jobs.insert(other.id.clone(), other.clone());
    m.save(&manifest).unwrap();

    let g = two_family_graph();
    let fresh = SchedulerOptions {
        jobs: 1,
        manifest_path: Some(manifest.clone()),
        resume: false,
        ..Default::default()
    };
    execute(&g, &fresh, &MockRunner::default()).unwrap().require_ok(&g).unwrap();
    let back = RunManifest::load(&manifest);
    assert_eq!(back.jobs.get(&other.id), Some(&other), "--fresh must not erase other targets");
    assert!(back.jobs.contains_key("a0"), "this run's cells are persisted too");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fresh_mode_ignores_the_manifest() {
    let dir = std::env::temp_dir().join("grades_sched_fresh_test");
    std::fs::remove_dir_all(&dir).ok();
    let manifest = dir.join("run_manifest.json");
    let g = two_family_graph();
    let resume_opts = SchedulerOptions {
        jobs: 1,
        manifest_path: Some(manifest.clone()),
        ..Default::default()
    };
    execute(&g, &resume_opts, &MockRunner::default()).unwrap();
    let fresh_opts = SchedulerOptions { resume: false, ..resume_opts };
    let runner = MockRunner::default();
    execute(&g, &fresh_opts, &runner).unwrap().require_ok(&g).unwrap();
    assert_eq!(runner.started().len(), g.len(), "--fresh re-runs everything");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn eval_jobs_outlive_their_training_job_and_receive_its_weights() {
    // train jobs a/b export their final weights; standalone eval jobs
    // score them later on the worker pool — possibly long after the
    // training job completed and released its (mock) device resources.
    let mut g = JobGraph::new();
    let a = g.add(train("a")).unwrap();
    let b = g.add(train("b")).unwrap();
    let ea = g.add(JobSpec::score("a/eval", "fake-cfg", EvalKind::LmSuites, a)).unwrap();
    let eb = g.add(JobSpec::score("b/eval", "fake-cfg", EvalKind::LmSuites, b)).unwrap();
    g.validate().unwrap();
    for jobs in [1, 4] {
        let runner = MockRunner::default();
        let report = execute(&g, &opts(jobs), &runner).unwrap();
        report.require_ok(&g).unwrap();
        let order = runner.started();
        let pos = |id: &str| order.iter().position(|x| x == id).unwrap();
        assert!(pos("a") < pos("a/eval"));
        assert!(pos("b") < pos("b/eval"));
        // the delivered payload (not some fresh state) determined the score
        let accs = result_set(&g, &report.statuses);
        assert_eq!(accs["a/eval"], fake_acc("a") as f32 as f64);
        assert_eq!(accs["b/eval"], fake_acc("b") as f32 as f64);
        // eval jobs also carry a result for the drivers
        assert!(report.result(ea).is_ok());
        assert!(report.result(eb).is_ok());
    }
}

#[test]
fn failed_training_job_skips_its_eval_job() {
    let mut g = JobGraph::new();
    let a = g.add(train("a")).unwrap();
    g.add(JobSpec::score("a/eval", "fake-cfg", EvalKind::LmSuites, a)).unwrap();
    let b = g.add(train("b")).unwrap();
    let runner = MockRunner {
        fail_on: ["a".to_string()].into_iter().collect(),
        ..Default::default()
    };
    let report = execute(&g, &opts(2), &runner).unwrap();
    assert!(matches!(report.statuses[a], JobStatus::Failed(_)));
    assert!(matches!(report.statuses[a + 1], JobStatus::Skipped(_)));
    assert!(matches!(report.statuses[b], JobStatus::Done { .. }));
}

#[test]
fn train_jobs_feeding_eval_jobs_never_resume_from_the_manifest() {
    // The eval payload (final weights) is not persisted, so a resumed
    // train job could never feed its eval dependent — both must re-run.
    let dir = std::env::temp_dir().join("grades_sched_eval_resume_test");
    std::fs::remove_dir_all(&dir).ok();
    let manifest = dir.join("run_manifest.json");
    let sopts = SchedulerOptions {
        jobs: 1,
        manifest_path: Some(manifest.clone()),
        ..Default::default()
    };
    let mut g = JobGraph::new();
    let a = g.add(train("a")).unwrap();
    g.add(JobSpec::score("a/eval", "fake-cfg", EvalKind::LmSuites, a)).unwrap();
    g.add(train("plain")).unwrap();

    execute(&g, &sopts, &MockRunner::default()).unwrap().require_ok(&g).unwrap();
    let second = MockRunner::default();
    execute(&g, &sopts, &second).unwrap().require_ok(&g).unwrap();
    // "plain" resumed; the exporting train job and its eval re-ran
    assert_eq!(second.started(), vec!["a".to_string(), "a/eval".to_string()]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn one_panicking_job_does_not_poison_the_pool() {
    let mut g = JobGraph::new();
    let a = g.add(train("a")).unwrap();
    g.add(train("b")).unwrap();
    let _c = g.add(train("c").after(a)).unwrap();
    g.add(train("d")).unwrap();

    for jobs in [1, 3] {
        let runner = MockRunner {
            panic_on: ["a".to_string()].into_iter().collect(),
            ..Default::default()
        };
        let report = execute(&g, &opts(jobs), &runner).unwrap();
        let ids = |pred: &dyn Fn(&JobStatus) -> bool| -> Vec<String> {
            report
                .statuses
                .iter()
                .enumerate()
                .filter(|(_, s)| pred(s))
                .map(|(i, _)| g.get(i).id.clone())
                .collect::<Vec<_>>()
        };
        assert_eq!(ids(&|s| matches!(s, JobStatus::Failed(_))), vec!["a"]);
        assert_eq!(ids(&|s| matches!(s, JobStatus::Skipped(_))), vec!["c"]);
        assert_eq!(ids(&|s| matches!(s, JobStatus::Done { .. })), vec!["b", "d"]);
        assert!(report.require_ok(&g).is_err());
        assert!(report.result(a).is_err());
    }
}

#[test]
fn failed_jobs_are_not_persisted_and_retry_on_resume() {
    let dir = std::env::temp_dir().join("grades_sched_retry_test");
    std::fs::remove_dir_all(&dir).ok();
    let manifest = dir.join("run_manifest.json");
    let sopts = SchedulerOptions {
        jobs: 2,
        manifest_path: Some(manifest.clone()),
        ..Default::default()
    };
    let mut g = JobGraph::new();
    g.add(train("good")).unwrap();
    g.add(train("flaky")).unwrap();

    let runner = MockRunner {
        fail_on: ["flaky".to_string()].into_iter().collect(),
        ..Default::default()
    };
    assert!(execute(&g, &sopts, &runner).unwrap().require_ok(&g).is_err());

    // Re-run without the failure: only the flaky job executes.
    let retry = MockRunner::default();
    execute(&g, &sopts, &retry).unwrap().require_ok(&g).unwrap();
    assert_eq!(retry.started(), vec!["flaky".to_string()]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn transient_failures_are_retried_within_the_run() {
    let dir = std::env::temp_dir().join("grades_sched_flaky_test");
    std::fs::remove_dir_all(&dir).ok();
    let manifest = dir.join("run_manifest.json");
    let sopts = SchedulerOptions {
        jobs: 2,
        manifest_path: Some(manifest.clone()),
        retry: RetryPolicy { max_attempts: 3, backoff_base_ms: 1, backoff_max_ms: 4 },
        ..Default::default()
    };
    let mut g = JobGraph::new();
    let flaky = g.add(train("flaky")).unwrap();
    g.add(train("steady")).unwrap();

    // Fails twice, then succeeds — within the 3-attempt budget.
    let runner = MockRunner {
        flaky: Mutex::new([("flaky".to_string(), 2)].into_iter().collect()),
        ..Default::default()
    };
    let report = execute(&g, &sopts, &runner).unwrap();
    report.require_ok(&g).unwrap();
    assert_eq!(
        runner.started().iter().filter(|id| *id == "flaky").count(),
        3,
        "two failed attempts plus the success"
    );
    // The attempt count is recorded on the summary and in the manifest,
    // and a successful completion clears the fault ledger.
    match &report.statuses[flaky] {
        JobStatus::Done { summary: Some(s), .. } => assert_eq!(s.attempts, 3),
        _ => panic!("flaky job did not complete with a summary"),
    }
    let m = RunManifest::load(&manifest);
    assert_eq!(m.jobs["flaky"].attempts, 3);
    assert!(m.faults.is_empty(), "success must clear the fault ledger");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn retry_budget_exhaustion_fails_the_job_and_records_the_ledger() {
    let dir = std::env::temp_dir().join("grades_sched_budget_test");
    std::fs::remove_dir_all(&dir).ok();
    let manifest = dir.join("run_manifest.json");
    let sopts = SchedulerOptions {
        jobs: 1,
        manifest_path: Some(manifest.clone()),
        retry: RetryPolicy { max_attempts: 2, backoff_base_ms: 0, backoff_max_ms: 0 },
        ..Default::default()
    };
    let mut g = JobGraph::new();
    let doomed = g.add(train("doomed")).unwrap();
    let dep = g.add(train("dependent").after(doomed)).unwrap();
    let runner = MockRunner {
        fail_on: ["doomed".to_string()].into_iter().collect(),
        ..Default::default()
    };
    let report = execute(&g, &sopts, &runner).unwrap();
    assert_eq!(
        runner.started().iter().filter(|id| *id == "doomed").count(),
        2,
        "the budget bounds the attempts"
    );
    assert!(matches!(report.statuses[doomed], JobStatus::Failed(_)));
    assert!(matches!(report.statuses[dep], JobStatus::Skipped(_)));
    // The exhausted job leaves a post-mortem in the manifest's ledger.
    let m = RunManifest::load(&manifest);
    let rec = m.faults.get("doomed").expect("exhausted job leaves a fault record");
    assert_eq!(rec.attempts, 2);
    assert!(rec.last_error.contains("mock failure"), "ledger keeps the error: {}", rec.last_error);
    std::fs::remove_dir_all(&dir).ok();
}
