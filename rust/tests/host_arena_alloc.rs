//! The zero-allocation acceptance test for the host engine's workspace
//! arena (`runtime::host_arena`), in its own test binary because it
//! installs a process-wide counting `#[global_allocator]` and reads
//! process-global counters — a single `#[test]` keeps concurrent test
//! threads from polluting the per-step deltas. (Integration tests are
//! compiled with `cfg(test)`, so the counting allocator never exists in
//! the shipped library.)
//!
//! What it pins, on a real `Session` training loop:
//!
//! 1. after a short warm-up, every steady-state train step serves *all*
//!    of its workspace from the arena's free lists — the fresh-bytes
//!    counter stays exactly flat, and total heap traffic per step
//!    collapses to a small residue (batch/ctrl staging, a few f64
//!    scratch vectors) far below the first step's;
//! 2. `StepTimings` surfaces the same accounting (`arena_carved_bytes`
//!    / `arena_fresh_bytes`);
//! 3. with the arena disabled (`GRADES_HOST_ARENA=0` semantics via the
//!    test override), every step allocates its full workspace fresh.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use grades::config::RepoConfig;
use grades::coordinator::scheduler::StepPlan;
use grades::data;
use grades::runtime::backend::Backend;
use grades::runtime::host_arena;
use grades::runtime::host_backend::HostBackend;
use grades::runtime::session::Session;

/// Counts cumulative allocated bytes (allocations only — frees don't
/// subtract, so the counter is monotone and deltas measure traffic,
/// not footprint).
struct CountingAlloc;

static ALLOCATED_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if new_size > layout.size() {
            ALLOCATED_BYTES.fetch_add((new_size - layout.size()) as u64, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocated() -> u64 {
    ALLOCATED_BYTES.load(Ordering::Relaxed)
}

#[test]
fn steady_state_train_steps_stop_heap_growth() {
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let b = HostBackend::for_config(&cfg).unwrap();
    let m = b.manifest();
    let mut ds = data::build_lm(&cfg, m).unwrap();
    let batch = ds.train.next_batch();
    let plan = StepPlan::all_active(m.n_components);
    let ctrl = |t: f32| {
        let mut c = vec![0f32; m.ctrl_len];
        c[0] = t;
        c[1] = 1e-3;
        c[2] = 1.0;
        for x in c.iter_mut().skip(m.ctrl_mask_offset) {
            *x = 1.0;
        }
        c
    };

    host_arena::set_arena_override(Some(true));
    let mut s = Session::new(&b);
    s.init(5).unwrap();

    // Step 1 populates the pools: the full workspace is fresh.
    let (_, f0) = host_arena::arena_counters();
    let a0 = allocated();
    s.train_step(&batch, &ctrl(1.0), &plan).unwrap();
    let (_, f1) = host_arena::arena_counters();
    let step1_fresh = f1 - f0;
    let step1_alloc = allocated() - a0;
    assert!(step1_fresh > 0, "first step must build its workspace fresh");

    // Warm-up: peak live counts per buffer size can still grow a little.
    for t in 2..=3 {
        s.train_step(&batch, &ctrl(t as f32), &plan).unwrap();
    }

    // Steady state: zero fresh arena bytes, and total heap traffic per
    // step (batch/ctrl staging, small f64 scratch, Rc bookkeeping) far
    // below the first step's workspace build.
    for t in 4..=8 {
        let (_, fa) = host_arena::arena_counters();
        let aa = allocated();
        s.train_step(&batch, &ctrl(t as f32), &plan).unwrap();
        let (_, fb) = host_arena::arena_counters();
        assert_eq!(fb - fa, 0, "step {t} allocated fresh arena bytes");
        let step_alloc = allocated() - aa;
        assert!(
            step_alloc * 4 < step1_alloc,
            "step {t} heap traffic {step_alloc}B is not far below step 1's {step1_alloc}B"
        );
    }

    // The timings surface carries the same accounting.
    let tm = s.timings();
    assert!(tm.arena_carved_bytes > 0, "steady-state carves must be visible in StepTimings");
    assert!(
        tm.arena_fresh_bytes >= step1_fresh,
        "StepTimings must account the step-1 workspace build"
    );

    // Disabled arena: the same step allocates its whole workspace fresh.
    host_arena::set_arena_override(Some(false));
    let (_, fa) = host_arena::arena_counters();
    s.train_step(&batch, &ctrl(9.0), &plan).unwrap();
    let (_, fb) = host_arena::arena_counters();
    assert!(
        fb - fa >= step1_fresh,
        "disabled arena must allocate every buffer fresh ({}B < {}B)",
        fb - fa,
        step1_fresh
    );
    host_arena::set_arena_override(None);
}
