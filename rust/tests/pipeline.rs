//! Host-side tests for the pipelined runtime — no PJRT artifacts needed,
//! so these always run under tier-1 `cargo test`.
//!
//! (The device-equivalence half of the pipeline coverage — cached eval ==
//! uncached eval, parallel == sequential compile, pipelined trajectory ==
//! synchronous trajectory — lives in `integration.rs` behind
//! `GRADES_ARTIFACTS=1`.)

use grades::data::batcher::{pack_rows, BatchIter};
use grades::data::corpus::generate;
use grades::data::vocab::Vocab;
use grades::runtime::pipeline::{BatchSource, FixedCycle, FnSource, Prefetcher};
use grades::runtime::session::{decode_checkpoint, encode_checkpoint, Batch};

fn corpus_iter(seed: u64, batch_size: usize) -> BatchIter {
    let v = Vocab::build(256).unwrap();
    let ss = generate(&v, 3, 60);
    BatchIter::new(pack_rows(&ss, 32), batch_size, seed)
}

#[test]
fn prefetcher_matches_inline_over_many_epochs() {
    // Real corpus rows, shuffled epochs, a consumer slower than the
    // producer: the prefetched stream must be batch-for-batch identical.
    let mut inline = corpus_iter(0xfeed, 4);
    let mut pre = Prefetcher::spawn(corpus_iter(0xfeed, 4), 3);
    for step in 0..4 * inline.n_rows() {
        let a = inline.next_batch();
        let b = pre.next_batch();
        assert_eq!(a.tokens, b.tokens, "diverged at step {step}");
        assert_eq!(a.targets, b.targets, "diverged at step {step}");
        if step % 7 == 0 {
            // let the producer run ahead and fill the channel
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
    assert!(inline.epoch >= 3, "must cover multiple reshuffled epochs");
}

#[test]
fn prefetcher_over_fixed_cycle_preserves_vlm_order() {
    let batches: Vec<Batch> = (0..5)
        .map(|i| Batch {
            tokens: vec![i; 4],
            targets: vec![i; 4],
            patches: vec![i as f32; 8],
        })
        .collect();
    let mut inline = FixedCycle::new(batches.clone());
    let mut pre = Prefetcher::spawn(FixedCycle::new(batches), 2);
    for _ in 0..12 {
        let a = inline.next_batch();
        let b = pre.next_batch();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.patches, b.patches);
    }
}

#[test]
fn sources_compose_as_trait_objects() {
    // The trainer consumes `&mut dyn BatchSource`; every source kind must
    // be usable behind the trait object, including a prefetched one.
    let mk = |i: i32| Batch { tokens: vec![i], targets: vec![i], patches: Vec::new() };
    let mut k = 0;
    let mut closure = FnSource(move || {
        k += 1;
        mk(k)
    });
    let mut cycle = FixedCycle::new(vec![mk(7)]);
    let mut pre = Prefetcher::spawn(FixedCycle::new(vec![mk(9)]), 1);
    let sources: Vec<&mut dyn BatchSource> = vec![&mut closure, &mut cycle, &mut pre];
    let first: Vec<i32> = sources.into_iter().map(|s| s.next_batch().tokens[0]).collect();
    assert_eq!(first, vec![1, 7, 9]);
}

#[test]
fn dropping_unconsumed_prefetcher_terminates_cleanly() {
    for depth in [1, 2, 8] {
        let pre = Prefetcher::spawn(corpus_iter(1, 2), depth);
        drop(pre); // worker may be mid-send; Drop must join without hanging
    }
}

#[test]
fn checkpoint_codec_roundtrips_large_state() {
    // > one encode chunk, exercised through the same helpers
    // `save_checkpoint` streams through.
    let state: Vec<f32> = (0..200_000).map(|i| (i as f32) * 0.25 - 1e3).collect();
    let bytes = encode_checkpoint(123, &state);
    assert_eq!(bytes.len(), 8 + 4 * state.len());
    let (step, back) = decode_checkpoint(&bytes).unwrap();
    assert_eq!(step, 123);
    assert_eq!(back, state);
}
