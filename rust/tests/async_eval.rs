//! Host-side tests for the asynchronous chunked-evaluation runtime — no
//! PJRT artifacts needed, so these always run under tier-1 `cargo test`.
//!
//! The validator is generic over the snapshot type and fed by closures,
//! so the full decision pipeline — `ClassicEs` checks issued through an
//! `AsyncValidator`, results applied under a `StalenessBound` — runs here
//! against synthetic losses. The device-equivalence half (async k = 0
//! trajectories == the synchronous trainer through real artifacts) lives
//! in `integration.rs` behind `GRADES_ARTIFACTS=1`.

use grades::config::EsConfig;
use grades::coordinator::classic_es::ClassicEs;
use grades::runtime::async_eval::{AsyncEvalOptions, AsyncValidator};

const N_BATCHES: usize = 7;
const TOTAL_STEPS: usize = 200;

fn es_cfg() -> EsConfig {
    EsConfig { check_interval_frac: 0.05, patience: 2, min_delta: 0.01 }
}

/// Synthetic per-batch loss for the parameters at `snapshot_step`:
/// improves, then stalls — so classic ES stops mid-run. Deliberately
/// awkward floats so bitwise comparisons are meaningful.
fn loss(snapshot_step: usize, batch: usize) -> (f64, f64) {
    let base = if snapshot_step <= 60 {
        3.0 - (snapshot_step as f64) * 0.031
    } else {
        1.14 + (snapshot_step as f64) * 1e-4
    };
    let count = 2.0 + (batch % 3) as f64;
    ((base + (batch as f64) * 0.0173) * count, count)
}

struct Run {
    /// (issued_at, val_loss bits) in application order.
    val_points: Vec<(usize, u64)>,
    /// Step the loop ended at.
    stop_step: usize,
    /// True when classic ES fired (vs budget exhaustion).
    stopped: bool,
}

/// The pre-async trainer's synchronous semantics, hand-rolled: a full
/// pass (summed in batch order) on the critical path of every check step.
fn run_sync() -> Run {
    let mut es = ClassicEs::new(&es_cfg(), TOTAL_STEPS);
    let mut val_points = Vec::new();
    for t in 1..=TOTAL_STEPS {
        if es.due(t) {
            let (mut ls, mut cs) = (0.0, 0.0);
            for i in 0..N_BATCHES {
                let (l, c) = loss(t, i);
                ls += l;
                cs += c;
            }
            let v = ls / cs;
            val_points.push((t, v.to_bits()));
            if es.record(v, 0.0) {
                return Run { val_points, stop_step: t, stopped: true };
            }
        }
    }
    Run { val_points, stop_step: TOTAL_STEPS, stopped: false }
}

/// The async trainer loop shape: issue on due, advance chunks each step,
/// apply completed results to the same `ClassicEs`. `break_at` simulates
/// another stop cause (e.g. the GradES monitor freezing the matrix)
/// ending the loop regardless of validation.
fn run_async_with(
    opts: AsyncEvalOptions,
    break_at: Option<usize>,
) -> (Run, AsyncValidator<usize>) {
    let mut es = ClassicEs::new(&es_cfg(), TOTAL_STEPS);
    let mut v: AsyncValidator<usize> = AsyncValidator::new(opts, N_BATCHES);
    let mut val_points = Vec::new();
    for t in 1..=TOTAL_STEPS {
        if break_at == Some(t) {
            v.abandon();
            return (Run { val_points, stop_step: t, stopped: false }, v);
        }
        let due = es.due(t);
        if due || v.in_flight().is_some() {
            let results = v
                .on_step(t, due, || Ok(t), |&s, i| Ok(loss(s, i)))
                .expect("synthetic eval cannot fail");
            let mut stop = false;
            for r in &results {
                val_points.push((r.issued_at, r.val_loss.to_bits()));
                if es.record(r.val_loss, 0.0) {
                    stop = true;
                }
            }
            if stop {
                return (Run { val_points, stop_step: t, stopped: true }, v);
            }
        }
    }
    v.abandon();
    (Run { val_points, stop_step: TOTAL_STEPS, stopped: false }, v)
}

fn run_async(opts: AsyncEvalOptions) -> (Run, AsyncValidator<usize>) {
    run_async_with(opts, None)
}

#[test]
fn staleness_zero_is_bitwise_identical_to_the_synchronous_loop() {
    let sync = run_sync();
    assert!(sync.stopped, "the synthetic losses must trigger classic ES");
    let (async0, v) = run_async(AsyncEvalOptions::synchronous());
    assert_eq!(async0.val_points, sync.val_points, "val series must match bitwise");
    assert_eq!(async0.stop_step, sync.stop_step);
    assert_eq!(async0.stopped, sync.stopped);
    assert_eq!(v.stats.forced_drains, 0);
    assert_eq!(v.stats.abandoned, 0);
    assert_eq!(v.stats.issued, v.stats.completed);
}

#[test]
fn unbounded_staleness_same_decisions_applied_at_natural_completion() {
    // chunk 1 over 7 batches, checks every 10 steps: each pass completes
    // 7 steps after its check, before the next check comes due. The loss
    // *series* is identical to the synchronous run (snapshots pin the
    // check step's parameters); only the application step shifts.
    let sync = run_sync();
    let (a, v) = run_async(AsyncEvalOptions::overlapped(1, usize::MAX));
    assert_eq!(a.val_points, sync.val_points);
    assert!(a.stopped);
    assert_eq!(a.stop_step, sync.stop_step + N_BATCHES, "decision lands the pass length late");
    assert_eq!(v.stats.forced_drains, 0);
    assert_eq!(v.stats.displaced, 0);
}

#[test]
fn staleness_bound_caps_the_decision_lag() {
    let sync = run_sync();
    for k in [1usize, 3, 5] {
        let (a, v) = run_async(AsyncEvalOptions::overlapped(1, k));
        assert_eq!(a.val_points, sync.val_points, "k={k}");
        assert!(a.stopped, "k={k}");
        assert_eq!(a.stop_step, sync.stop_step + k, "k={k}: applied exactly k steps late");
        assert!(v.stats.forced_drains > 0, "k={k} < pass length forces drains");
    }
}

#[test]
fn chunk_size_trades_lag_without_changing_the_series() {
    let sync = run_sync();
    // chunk 4 over 7 batches: passes complete 2 steps after issue.
    let (a, _) = run_async(AsyncEvalOptions::overlapped(4, usize::MAX));
    assert_eq!(a.val_points, sync.val_points);
    assert_eq!(a.stop_step, sync.stop_step + 2);
}

#[test]
fn stop_signal_arriving_after_the_matrix_froze_is_discarded() {
    // The sync run stops at some check step T. Simulate GradES freezing
    // the whole matrix (loop break) one step after that check was issued
    // asynchronously: the in-flight pass must be abandoned, its stop
    // signal never applied, and nothing panics.
    let sync = run_sync();
    let freeze_step = sync.stop_step + 1;
    let (a, v) = run_async_with(AsyncEvalOptions::overlapped(1, usize::MAX), Some(freeze_step));
    assert!(!a.stopped, "validation must not have fired");
    assert_eq!(a.stop_step, freeze_step);
    assert_eq!(v.stats.abandoned, 1);
    assert!(v.in_flight().is_none());
    // every result that *was* applied matches the synchronous series
    assert_eq!(a.val_points, sync.val_points[..a.val_points.len()]);
}

#[test]
fn checks_run_and_best_loss_agree_across_modes() {
    let mut es_sync = ClassicEs::new(&es_cfg(), TOTAL_STEPS);
    let mut es_async = ClassicEs::new(&es_cfg(), TOTAL_STEPS);
    let sync = run_sync();
    for &(_, bits) in &sync.val_points {
        es_sync.record(f64::from_bits(bits), 0.0);
    }
    let (a, _) = run_async(AsyncEvalOptions::overlapped(2, usize::MAX));
    for &(_, bits) in &a.val_points {
        es_async.record(f64::from_bits(bits), 0.0);
    }
    assert_eq!(es_sync.checks_run, es_async.checks_run);
    assert_eq!(es_sync.best_loss().to_bits(), es_async.best_loss().to_bits());
}
