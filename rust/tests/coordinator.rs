//! Fault-injection tests for the coordinator/worker runtime. Host-only:
//! workers run the real `grades worker` binary (`CARGO_BIN_EXE_grades`)
//! in deterministic mock mode (`GRADES_MOCK_JOBS=1`), so these exercise
//! process spawning, the stdio wire protocol, leases/heartbeats, retry,
//! and crash recovery — everything except the engines.
//!
//! The core assertions mirror the robustness claims:
//! - a clean distributed run persists byte-identical manifest cells to a
//!   sequential in-process `--jobs 1` run of the same plan;
//! - a worker SIGKILLed mid-grid loses its lease, its job is reassigned,
//!   the run completes, and the tables still match the in-process run;
//! - a killed-and-restarted coordinator resumes from `run_manifest.json`
//!   alone without re-running completed jobs;
//! - when no worker can be spawned, execution degrades to the in-process
//!   pool.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use grades::coordinator::trainer::StoppingMethod;
use grades::exp::coordinator::{try_execute, Dispatch, GridOptions, MockOptions};
use grades::exp::fault::MockJobRunner;
use grades::exp::plan::{EvalKind, JobGraph, JobSpec};
use grades::exp::scheduler::{
    execute, JobStatus, JobSummary, RetryPolicy, RunManifest, RunReport, SchedulerOptions,
};
use grades::runtime::backend::BackendChoice;

/// Run-wide settings fingerprint shared by every run in this suite (it
/// must match between the coordinator, the workers, and the in-process
/// comparison runner for summaries and resume to line up).
const SETTINGS: &str = "fault-suite";

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("grades_coord_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn train(id: &str) -> JobSpec {
    JobSpec::train(id, "fake-cfg", StoppingMethod::GradEs, EvalKind::None)
}

/// pretrain → 4 dependents, plus an independent pretrain → 2 dependents —
/// enough width that two workers interleave and a killed worker's jobs
/// land on the survivor.
fn grid_graph() -> JobGraph {
    let mut g = JobGraph::new();
    let pre_a = g.add(JobSpec::pretrain("pre-a", "fake-cfg")).unwrap();
    for i in 0..4 {
        g.add(train(&format!("a{i}")).warm(pre_a)).unwrap();
    }
    let pre_b = g.add(JobSpec::pretrain("pre-b", "fake-cfg")).unwrap();
    for i in 0..2 {
        g.add(train(&format!("b{i}")).warm(pre_b)).unwrap();
    }
    g
}

/// Options for a distributed run: real worker binary, mock execution,
/// fast heartbeats, a manifest + execution log under `dir`.
fn dist_opts(dir: &Path, workers: usize, log: &str) -> SchedulerOptions {
    SchedulerOptions {
        jobs: 1,
        manifest_path: Some(dir.join("run_manifest.json")),
        settings: SETTINGS.to_string(),
        backend: BackendChoice::Host,
        verbose: false,
        workers,
        grid: GridOptions {
            worker_cmd: Some(vec![
                env!("CARGO_BIN_EXE_grades").to_string(),
                "worker".to_string(),
            ]),
            lease_ms: 5_000,
            heartbeat_ms: 100,
            // long enough that every worker is up before the grid drains,
            // so the fault target reliably reaches its Nth assignment
            mock: Some(MockOptions { sleep_ms: 25, log: Some(dir.join(log)) }),
            ..Default::default()
        },
        ..Default::default()
    }
}

/// The sequential in-process ground truth (`--jobs 1`, `--workers 0`).
fn in_process_report(dir: &Path) -> RunReport {
    let opts = SchedulerOptions {
        jobs: 1,
        manifest_path: Some(dir.join("seq_manifest.json")),
        settings: SETTINGS.to_string(),
        backend: BackendChoice::Host,
        ..Default::default()
    };
    let runner = MockJobRunner::new(SETTINGS, BackendChoice::Host);
    execute(&grid_graph(), &opts, &runner).unwrap()
}

fn must_run(d: Dispatch) -> RunReport {
    match d {
        Dispatch::Ran(r) => r,
        Dispatch::Fallback(why) => panic!("coordinator fell back: {why}"),
    }
}

/// Done-job summaries keyed by id, with `attempts` normalized to 1 so
/// fault runs compare equal to clean runs on every *result* field.
fn summaries(g: &JobGraph, r: &RunReport) -> BTreeMap<String, JobSummary> {
    let mut out = BTreeMap::new();
    for (i, s) in r.statuses.iter().enumerate() {
        if let JobStatus::Done { summary: Some(sm), .. } = s {
            let mut sm = sm.clone();
            sm.attempts = 1;
            out.insert(g.get(i).id.clone(), sm);
        }
    }
    out
}

/// Job ids logged by worker processes (the in-process runner never logs).
fn logged_ids(path: &Path) -> Vec<String> {
    let mut ids: Vec<String> = std::fs::read_to_string(path)
        .unwrap_or_default()
        .lines()
        .map(|l| l.to_string())
        .collect();
    ids.sort();
    ids
}

#[test]
fn distributed_run_matches_the_in_process_tables() {
    let dir = tmp_dir("clean");
    let g = grid_graph();
    let opts = dist_opts(&dir, 2, "mock_log.txt");
    let report = must_run(try_execute(&g, &opts).unwrap());
    report.require_ok(&g).unwrap();

    // Worker processes — not this process — executed every job.
    let ids = logged_ids(&dir.join("mock_log.txt"));
    assert_eq!(ids.len(), g.len(), "each job ran exactly once: {ids:?}");

    // Cell-level equality against the sequential in-process run…
    let seq = in_process_report(&dir);
    assert_eq!(summaries(&g, &report), summaries(&g, &seq));

    // …and byte-level equality of the persisted manifests.
    let dist_manifest = RunManifest::load(&dir.join("run_manifest.json"));
    let seq_manifest = RunManifest::load(&dir.join("seq_manifest.json"));
    assert!(dist_manifest.faults.is_empty());
    assert_eq!(dist_manifest.render(), seq_manifest.render(), "manifests are byte-identical");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sigkilled_worker_jobs_are_reassigned_and_tables_match_jobs_1() {
    let dir = tmp_dir("sigkill");
    let g = grid_graph();
    let mut opts = dist_opts(&dir, 2, "mock_log.txt");
    // Worker 0 SIGKILLs itself on its 2nd assignment: no unwind, no
    // farewell frame — the coordinator sees EOF mid-job.
    opts.grid.fault = Some("0:sigkill@2".to_string());
    let report = must_run(try_execute(&g, &opts).unwrap());
    report.require_ok(&g).unwrap();
    let (_, _, failed, skipped) = report.counts();
    assert_eq!((failed, skipped), (0, 0));

    // Exactly one job needed a second attempt (the one killed mid-run;
    // replacement workers get fresh indices, so the fault fires once).
    let retried: Vec<&str> = report
        .statuses
        .iter()
        .enumerate()
        .filter_map(|(i, s)| match s {
            JobStatus::Done { summary: Some(sm), .. } if sm.attempts > 1 => {
                Some(g.get(i).id.as_str())
            }
            _ => None,
        })
        .collect();
    assert_eq!(retried.len(), 1, "exactly one job was reassigned: {retried:?}");

    // The recovered run's tables are identical to the sequential run.
    let seq = in_process_report(&dir);
    assert_eq!(summaries(&g, &report), summaries(&g, &seq));

    // Success cleared the fault ledger; every train cell is persisted.
    let m = RunManifest::load(&dir.join("run_manifest.json"));
    assert!(m.faults.is_empty(), "ledger not cleared: {:?}", m.faults);
    assert_eq!(m.jobs.len(), 6, "all six train cells persisted");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hung_worker_loses_its_lease_and_the_job_is_reassigned() {
    let dir = tmp_dir("hang");
    let g = grid_graph();
    let mut opts = dist_opts(&dir, 2, "mock_log.txt");
    // Worker 0 stops heartbeating and sleeps forever on its 2nd
    // assignment: only lease expiry — not EOF — can detect this.
    opts.grid.fault = Some("0:hang@2".to_string());
    opts.grid.lease_ms = 600;
    let report = must_run(try_execute(&g, &opts).unwrap());
    report.require_ok(&g).unwrap();
    let seq = in_process_report(&dir);
    assert_eq!(summaries(&g, &report), summaries(&g, &seq));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn panic_and_garble_faults_are_contained() {
    for (name, fault) in [("panic", "0:panic@2"), ("garble", "0:garble@2")] {
        let dir = tmp_dir(name);
        let g = grid_graph();
        let mut opts = dist_opts(&dir, 2, "mock_log.txt");
        opts.grid.fault = Some(fault.to_string());
        let report = must_run(try_execute(&g, &opts).unwrap());
        report.require_ok(&g).unwrap_or_else(|e| panic!("{fault}: {e:#}"));
        let seq = in_process_report(&dir);
        assert_eq!(summaries(&g, &report), summaries(&g, &seq), "{fault}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn restarted_coordinator_resumes_from_the_manifest_without_rerunning() {
    let dir = tmp_dir("resume");
    let g = grid_graph();

    // Run 1: a single worker, so its first assignment is deterministically
    // pre-a (plan order). It dies with no retry budget, the a-family never
    // completes, a replacement finishes the b-family — then the
    // "coordinator" goes away. Everything it knows survives only in
    // run_manifest.json.
    let mut first = dist_opts(&dir, 1, "log_run1.txt");
    first.grid.fault = Some("0:sigkill@1".to_string());
    first.retry = RetryPolicy { max_attempts: 1, backoff_base_ms: 0, backoff_max_ms: 0 };
    let r1 = must_run(try_execute(&g, &first).unwrap());
    assert!(r1.require_ok(&g).is_err(), "the killed family must not complete");
    let (ran1, _, failed1, skipped1) = r1.counts();
    assert_eq!((ran1, failed1, skipped1), (3, 1, 4), "b-family completed, a-family died");
    let mid = RunManifest::load(&dir.join("run_manifest.json"));
    assert_eq!(mid.jobs.len(), 2, "b0/b1 cells persisted before the crash");
    assert!(mid.faults.contains_key("pre-a"), "the post-mortem is in the ledger");

    // Run 2: a fresh coordinator, same manifest, no fault. Only the
    // unfinished jobs may execute.
    let second = dist_opts(&dir, 2, "log_run2.txt");
    let r2 = must_run(try_execute(&g, &second).unwrap());
    r2.require_ok(&g).unwrap();
    let (ran2, resumed2, _, _) = r2.counts();
    assert_eq!((ran2, resumed2), (5, 3), "b-family resumed/elided, a-family ran");
    assert_eq!(
        logged_ids(&dir.join("log_run2.txt")),
        vec!["a0", "a1", "a2", "a3", "pre-a"],
        "completed jobs were not re-run"
    );

    // The recovered grid still matches the sequential ground truth, and
    // pre-a's completion cleared its ledger entry.
    let seq = in_process_report(&dir);
    assert_eq!(summaries(&g, &r2), summaries(&g, &seq));
    assert!(RunManifest::load(&dir.join("run_manifest.json")).faults.is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unspawnable_workers_degrade_to_the_in_process_pool() {
    let dir = tmp_dir("nospawn");
    let g = grid_graph();
    let mut opts = dist_opts(&dir, 2, "mock_log.txt");
    opts.grid.worker_cmd = Some(vec!["/nonexistent/grades-worker".to_string()]);

    // try_execute reports why…
    match try_execute(&g, &opts).unwrap() {
        Dispatch::Fallback(why) => assert!(why.contains("spawn"), "unexpected reason: {why}"),
        Dispatch::Ran(_) => panic!("no worker binary exists — this must fall back"),
    }

    // …and the public entry point silently completes on the pool.
    let runner = MockJobRunner::new(SETTINGS, BackendChoice::Host);
    let report = execute(&g, &opts, &runner).unwrap();
    report.require_ok(&g).unwrap();
    assert!(
        !dir.join("mock_log.txt").exists(),
        "no worker process ever ran a job"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn graphs_with_eval_jobs_are_not_distributable() {
    let dir = tmp_dir("evalgate");
    let mut g = JobGraph::new();
    let a = g.add(train("a")).unwrap();
    g.add(JobSpec::score("a/eval", "fake-cfg", EvalKind::LmSuites, a)).unwrap();
    let opts = dist_opts(&dir, 2, "mock_log.txt");
    match try_execute(&g, &opts).unwrap() {
        Dispatch::Fallback(_) => {}
        Dispatch::Ran(_) => panic!("eval graphs need in-memory weight handoff"),
    }
    std::fs::remove_dir_all(&dir).ok();
}
