//! The stopping-method zoo, end to end on the host backend — no
//! artifacts, no Python, always green under tier-1 `cargo test`.
//!
//! Covers the label/parse round-trip for all six methods, clean errors
//! (not panics) when a resumed `run_manifest.json` names a method this
//! build doesn't know, back-compat for manifests written before the
//! `val_checks` counter existed, real trainer trajectories for the three
//! new rules (EB criterion, spectral ES, instance-ES), and the scheduler
//! property the zoo table rides on: `--jobs 1` and `--jobs N` render
//! byte-identical tables.

use grades::config::RepoConfig;
use grades::coordinator::trainer::{
    self, StopCause, StoppingMethod, TrainerOptions, ALL_METHODS,
};
use grades::data;
use grades::exp::ablation::{zoo_row, zoo_table_header};
use grades::exp::fault::mock_summary;
use grades::exp::plan::{EvalKind, JobGraph, JobSpec};
use grades::exp::scheduler::{self, JobSummary};
use grades::exp::ExpOptions;
use grades::runtime::backend::{Backend, BackendChoice};
use grades::runtime::host_backend::HostBackend;
use grades::util::json::{self, Json};

fn backend(config: &str) -> HostBackend {
    let cfg = RepoConfig::by_name(config).expect("config");
    HostBackend::for_config(&cfg).expect("host backend")
}

#[test]
fn method_labels_round_trip_for_all_six() {
    let labels: Vec<&str> = ALL_METHODS.iter().map(|m| m.label()).collect();
    assert_eq!(labels, vec!["base", "es", "grades", "eb", "spectral", "ies"]);
    for m in ALL_METHODS {
        assert_eq!(StoppingMethod::parse(m.label()), Some(m));
    }
    assert_eq!(StoppingMethod::parse("none"), Some(StoppingMethod::None));
    assert_eq!(StoppingMethod::parse("warp"), None);
    assert_eq!(StoppingMethod::parse(""), None);
}

#[test]
fn resumed_manifest_with_unknown_method_fails_cleanly() {
    // A manifest written by a *newer* build (or a corrupted one) names a
    // method this build doesn't have: loading stays fine, reconstruction
    // must be a clean error naming the method — never a panic.
    let spec = JobSpec::train("zoo/x/base", "lm-tiny-fp", StoppingMethod::None, EvalKind::None);
    let mut s = mock_summary(&spec, "", BackendChoice::Host);
    s.method = "warp".to_string();
    let round = JobSummary::from_json(&json::parse(&json::write(&s.to_json())).unwrap()).unwrap();
    let err = round.to_result().unwrap_err().to_string();
    assert!(err.contains("unknown stopping method"), "got: {err}");
    assert!(err.contains("warp"), "got: {err}");
}

#[test]
fn pre_zoo_manifest_without_val_checks_loads_as_zero() {
    let spec = JobSpec::train("zoo/x/es", "lm-tiny-fp", StoppingMethod::ClassicEs, EvalKind::None);
    let mut s = mock_summary(&spec, "", BackendChoice::Host);
    s.val_checks = 3;
    let mut j = s.to_json();
    if let Json::Obj(m) = &mut j {
        m.remove("val_checks"); // simulate a manifest from before the field
    }
    let back = JobSummary::from_json(&j).unwrap();
    assert_eq!(back.val_checks, 0);
    // and the reconstructed outcome mirrors the counter
    assert_eq!(back.to_result().unwrap().outcome.async_eval.issued, 0);
}

#[test]
fn eb_criterion_freezes_and_terminates_without_validation() {
    // margin = -∞: every finite evidence value exceeds it, so every
    // post-grace probe counts and the run terminates right after ⌈αT⌉ —
    // the EB analogue of the τ = ∞ GradES test, and like GradES it must
    // issue zero validation passes.
    let b = backend("lm-tiny-fp");
    let mut cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    cfg.eb.alpha = 0.2;
    cfg.eb.margin = f64::NEG_INFINITY;
    cfg.eb.patience = 0;
    let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::EbCriterion);
    assert!(opts.elide_frozen, "EB freezing must drive step-plan elision");
    opts.total_steps = 25;
    opts.final_validation = false;
    let o = trainer::run(&b, &cfg, &opts, || ds.train.next_batch(), &[]).unwrap();
    assert_eq!(o.stop_cause, StopCause::AllComponentsFrozen);
    assert_eq!(o.steps_run, 6, "all components freeze at grace+1 = 6");
    assert!(o.freeze.all_frozen());
    assert_eq!(o.async_eval.issued, 0, "EB must be validation-free");
    assert_eq!(o.validation_secs, 0.0);
}

#[test]
fn spectral_es_freezes_on_static_spectra() {
    // τ huge: any drift below it counts as converged, so every component
    // freezes at its second scan (the first only stores the baseline).
    let b = backend("lm-tiny-fp");
    let mut cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    cfg.spectral.alpha = 0.2;
    cfg.spectral.interval_frac = 0.08; // scan every 2 steps at T = 25
    cfg.spectral.tau = 1e9;
    cfg.spectral.patience = 0;
    let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::SpectralEs);
    assert!(opts.elide_frozen);
    opts.total_steps = 25;
    opts.final_validation = false;
    let o = trainer::run(&b, &cfg, &opts, || ds.train.next_batch(), &[]).unwrap();
    assert_eq!(o.stop_cause, StopCause::AllComponentsFrozen);
    assert!(o.freeze.all_frozen());
    // grace 5, scans at 6 (baseline) and 8 (freeze): early termination
    assert_eq!(o.steps_run, 8, "freeze at the second scan");
    assert_eq!(o.async_eval.issued, 0, "spectral ES is validation-free");
    assert!(o.monitor_secs > 0.0, "scans are accounted as monitoring");
}

#[test]
fn instance_es_excludes_rows_and_stops_on_exhaustion() {
    // Cycle 2 fixed batches; drop_frac 1 with patience 0 excludes every
    // row of a checked batch at once. With a 2-step check cadence the
    // checks always land on the second batch, so the excluded fraction
    // of seen rows reaches ~1/2 at the first check — stop_frac below
    // that fires SamplesExhausted right there.
    let b = backend("lm-tiny-fp");
    let mut cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    cfg.ies.alpha = 0.0;
    cfg.ies.check_interval_frac = 0.05; // check every 2 steps at T = 40
    cfg.ies.drop_frac = 1.0;
    cfg.ies.patience = 0;
    cfg.ies.stop_frac = 0.4;
    let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
    let batches = [ds.train.next_batch(), ds.train.next_batch()];
    let mut i = 0usize;
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::InstanceEs);
    assert!(!opts.elide_frozen, "IES freezes rows, not components");
    opts.total_steps = 40;
    opts.final_validation = false;
    let o = trainer::run(
        &b,
        &cfg,
        &opts,
        || {
            let b = batches[i % 2].clone();
            i += 1;
            b
        },
        &[],
    )
    .unwrap();
    assert_eq!(o.stop_cause, StopCause::SamplesExhausted);
    assert!(o.steps_run < 40, "stopped early at {}", o.steps_run);
    assert_eq!(o.async_eval.issued, 0, "IES scores train rows, not val");
    assert!(o.monitor_secs > 0.0, "row scoring is accounted as monitoring");
}

#[test]
fn zoo_tables_are_byte_identical_across_job_counts() {
    // The full six-method zoo through the real scheduler + host runner,
    // sequentially and on a 4-worker pool: rendered tables must be
    // byte-identical (the equality the bench gates in CI, pinned here
    // with a scaled-down budget).
    let mut g = JobGraph::new();
    let mut ids = Vec::new();
    for method in ALL_METHODS {
        ids.push(
            g.add(JobSpec::train(
                format!("zoo/lm-tiny-fp/{}", method.label()),
                "lm-tiny-fp",
                method,
                EvalKind::LmSuites,
            ))
            .unwrap(),
        );
    }
    let mut opts = ExpOptions::quick(12, 4);
    opts.backend = BackendChoice::Host;
    let runner = scheduler::DeviceRunner::new(&opts);
    let sopts = |jobs: usize| scheduler::SchedulerOptions {
        jobs,
        manifest_path: None,
        resume: false,
        backend: BackendChoice::Host,
        ..Default::default()
    };
    // Wall clock is the one legitimately nondeterministic cell — blank
    // it; everything else must agree to the byte.
    let render = |report: &scheduler::RunReport| -> String {
        let mut t = zoo_table_header();
        for &id in &ids {
            let mut row = zoo_row("lm-tiny-fp", report.result(id).unwrap());
            row[2] = "-".to_string();
            t.row(row);
        }
        t.render()
    };
    let seq = scheduler::execute(&g, &sopts(1), &runner).unwrap();
    seq.require_ok(&g).unwrap();
    let conc = scheduler::execute(&g, &sopts(4), &runner).unwrap();
    conc.require_ok(&g).unwrap();
    assert_eq!(render(&seq), render(&conc), "zoo tables diverged across --jobs");
    // the headline column: gradient-signal methods issue no validation
    for (&id, method) in ids.iter().zip(ALL_METHODS.iter()) {
        if matches!(method, StoppingMethod::GradEs | StoppingMethod::EbCriterion) {
            let r = seq.result(id).unwrap();
            assert_eq!(r.outcome.async_eval.issued, 0, "{} validated", method.label());
        }
    }
}
