//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These need Python-built artifacts, so they are opt-in: set
//! `GRADES_ARTIFACTS=1` (after `make artifacts`) to run them; otherwise
//! every test here skips with a message and `cargo test -q` stays green
//! on a fresh checkout. They exercise the full L3→L2→L1 stack: init
//! determinism, train-step semantics through the compiled graphs,
//! freeze-mask behaviour, the attn-frozen variant, checkpoint
//! round-trips, warm starts, the trainer's three stopping methods, and
//! the pipelined runtime's equivalence guarantees.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use grades::config::RepoConfig;
use grades::coordinator::scheduler::StepPlan;
use grades::coordinator::trainer::{self, StopCause, StoppingMethod, TrainerOptions};
use grades::coordinator::warmstart::BaseCheckpoint;
use grades::data;
use grades::eval::{benchmarks, harness};
use grades::runtime::artifact::{Bundle, Client};
use grades::runtime::async_eval::{AsyncEvalOptions, StalenessBound};
use grades::runtime::pipeline::{DeviceBatchCache, PipelineOptions, Prefetcher};
use grades::runtime::session::Session;

// PjRtClient is !Send (Rc internals): cache per test thread.
thread_local! {
    static CLIENT: Client = Client::cpu().expect("PJRT CPU client");
    static BUNDLES: RefCell<BTreeMap<String, Rc<Bundle>>> = RefCell::new(BTreeMap::new());
}

/// Artifact-dependent tests are env-gated so a checkout without the
/// Python toolchain still gets a meaningful (green) tier-1 run instead of
/// a wall of expected failures masking real regressions.
fn artifacts_enabled() -> bool {
    matches!(std::env::var("GRADES_ARTIFACTS"), Ok(v) if !v.is_empty() && v != "0")
}

fn bundle(name: &str) -> Option<Rc<Bundle>> {
    if !artifacts_enabled() {
        eprintln!("skipping: set GRADES_ARTIFACTS=1 (after `make artifacts`) to run artifact tests");
        return None;
    }
    BUNDLES.with(|cell| {
        let mut map = cell.borrow_mut();
        if let Some(b) = map.get(name) {
            return Some(b.clone());
        }
        let dir = grades::config::repo_root().join("artifacts").join(name);
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts/{name} missing (run `make artifacts`)");
            return None;
        }
        let b = Rc::new(CLIENT.with(|c| Bundle::load(c, &dir)).expect("bundle"));
        map.insert(name.to_string(), b.clone());
        Some(b)
    })
}

fn full_plan(b: &Bundle) -> StepPlan {
    StepPlan::all_active(b.manifest.n_components)
}

fn attn_plan(b: &Bundle) -> StepPlan {
    let m = &b.manifest;
    StepPlan::omitting(m.n_components, &m.components_where(|c| c.group == "attention"))
}

fn default_ctrl(b: &Bundle, t: f32, lr: f32) -> Vec<f32> {
    let m = &b.manifest;
    let mut ctrl = vec![0f32; m.ctrl_len];
    ctrl[0] = t;
    ctrl[1] = lr;
    ctrl[2] = 1.0;
    for c in ctrl.iter_mut().skip(m.ctrl_mask_offset) {
        *c = 1.0;
    }
    ctrl
}

#[test]
fn init_is_deterministic_per_seed() {
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let mut s1 = Session::new(b);
    let mut s2 = Session::new(b);
    s1.init(7).unwrap();
    s2.init(7).unwrap();
    assert_eq!(s1.state_to_host().unwrap(), s2.state_to_host().unwrap());
    s2.init(8).unwrap();
    assert_ne!(s1.state_to_host().unwrap(), s2.state_to_host().unwrap());
}

#[test]
fn train_step_reduces_loss_on_repeated_batch() {
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, &b.manifest).unwrap();
    let batch = ds.train.next_batch();
    let mut s = Session::new(b);
    s.init(3).unwrap();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for t in 1..=10 {
        s.train_step(&batch, &default_ctrl(b, t as f32, 3e-3), &full_plan(b)).unwrap();
        let m = s.probe().unwrap();
        let loss = m[0] / m[1].max(1.0);
        if t == 1 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first - 0.5, "loss {first} -> {last}");
}

#[test]
fn freeze_mask_freezes_component_params() {
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let m = &b.manifest;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, m).unwrap();
    let batch = ds.train.next_batch();
    let mut s = Session::new(b);
    s.init(3).unwrap();
    let before = s.state_to_host().unwrap();
    let mut ctrl = default_ctrl(b, 1.0, 1e-3);
    ctrl[m.ctrl_mask_offset] = 0.0; // freeze component 0
    s.train_step(&batch, &ctrl, &full_plan(b)).unwrap();
    let after = s.state_to_host().unwrap();
    let comp = &m.components[0];
    for tname in &comp.tensors {
        let p = m.param(tname).unwrap();
        assert_eq!(
            before[p.offset..p.offset + p.size()],
            after[p.offset..p.offset + p.size()],
            "frozen tensor {tname} moved"
        );
    }
    // some other monitored tensor moved
    let other = &m.components[1].tensors[0];
    let p = m.param(other).unwrap();
    assert_ne!(before[p.offset..p.offset + p.size()], after[p.offset..p.offset + p.size()]);
}

#[test]
fn attn_frozen_variant_matches_masked_full_graph() {
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let m = &b.manifest;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, m).unwrap();
    let batch = ds.train.next_batch();

    let mut s1 = Session::new(b);
    s1.init(5).unwrap();
    let mut ctrl = default_ctrl(b, 1.0, 1e-3);
    for c in &m.components {
        if c.group == "attention" {
            ctrl[m.ctrl_mask_offset + c.idx] = 0.0;
        }
    }
    s1.train_step(&batch, &ctrl, &full_plan(b)).unwrap();

    let mut s2 = Session::new(b);
    s2.init(5).unwrap();
    s2.train_step(&batch, &default_ctrl(b, 1.0, 1e-3), &attn_plan(b)).unwrap();

    let h1 = s1.state_to_host().unwrap();
    let h2 = s2.state_to_host().unwrap();
    // params + opt state agree (metrics prefix reports attn stats as 0 in
    // the variant, so compare past the prefix)
    let off = m.metrics_len;
    let max_dev = h1[off..]
        .iter()
        .zip(&h2[off..])
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_dev < 2e-4, "variant deviates: {max_dev}");
}

#[test]
fn checkpoint_roundtrip_preserves_state() {
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, &b.manifest).unwrap();
    let mut s = Session::new(b);
    s.init(9).unwrap();
    for t in 1..=3 {
        let batch = ds.train.next_batch();
        s.train_step(&batch, &default_ctrl(b, t as f32, 1e-3), &full_plan(b)).unwrap();
    }
    let host = s.state_to_host().unwrap();
    let path = std::env::temp_dir().join("grades_it_ckpt.bin");
    s.save_checkpoint(&path).unwrap();
    let mut s2 = Session::new(b);
    s2.load_checkpoint(&path).unwrap();
    assert_eq!(s2.state_to_host().unwrap(), host);
    assert_eq!(s2.step, 3); // step counter restored from the header
}

#[test]
fn warm_start_transfers_base_params_to_lora() {
    let (Some(fp), Some(lora)) = (bundle("lm-tiny-fp"), bundle("lm-tiny-lora")) else { return };
    let (fp, lora) = (&*fp, &*lora);
    let mut s = Session::new(fp);
    s.init(11).unwrap();
    let ck = BaseCheckpoint::from_state(&fp.manifest, &s.state_to_host().unwrap()).unwrap();
    let mut sl = Session::new(lora);
    sl.init(12).unwrap();
    let applied = ck.apply(&mut sl).unwrap();
    // every fp tensor exists in the lora layout as a frozen base tensor
    assert_eq!(applied, fp.manifest.params.len());
    let host = sl.state_to_host().unwrap();
    let w_fp = fp.manifest.param("lang.0.attn.q").unwrap();
    let w_lora = lora.manifest.param("lang.0.attn.q").unwrap();
    assert_eq!(
        ck.params["lang.0.attn.q"],
        host[w_lora.offset..w_lora.offset + w_lora.size()].to_vec()
    );
    assert_eq!(w_fp.size(), w_lora.size());
}

#[test]
fn trainer_grades_freezes_and_terminates_early() {
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let mut cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    cfg.grades.alpha = 0.2;
    cfg.grades.tau = 5.0; // generous: everything freezes right after grace
    let mut ds = data::build_lm(&cfg, &b.manifest).unwrap();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    opts.total_steps = 60;
    let o = trainer::run(b, &cfg, &opts, || ds.train.next_batch(), &ds.val).unwrap();
    assert_eq!(o.stop_cause, StopCause::AllComponentsFrozen);
    assert!(o.steps_run < 40, "terminated at {}", o.steps_run);
    assert!(o.freeze.all_frozen());
    // savings come mostly from termination: spent << full-budget dense cost
    let full_budget = grades::coordinator::flops::FlopsCounter::dense_step(&b.manifest) * 60.0;
    assert!(o.flops.total() < full_budget * 0.75);
}

#[test]
fn trainer_classic_es_runs_validation() {
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, &b.manifest).unwrap();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::ClassicEs);
    opts.total_steps = 40;
    let o = trainer::run(b, &cfg, &opts, || ds.train.next_batch(), &ds.val).unwrap();
    assert!(o.validation_secs > 0.0);
    assert!(!o.log.val_points.is_empty());
    assert!(o.flops.validation > 0.0);
}

#[test]
fn mc_scoring_improves_with_training() {
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, &b.manifest).unwrap();
    let suites = benchmarks::lm_suites(&ds.vocab, 0x77, 24);

    let mut s = Session::new(b);
    s.init(13).unwrap();
    let acc_untrained = harness::score_suite(&s, &suites[7]).unwrap(); // FreqComp (easy)

    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::None);
    opts.total_steps = 120;
    opts.probe_every = usize::MAX;
    let trained =
        trainer::run_and_keep(b, &cfg, &opts, || ds.train.next_batch(), &[]).unwrap();
    let acc_trained = harness::score_suite(&trained.session, &suites[7]).unwrap();
    assert!(
        acc_trained > acc_untrained + 10.0,
        "training must lift easy-suite accuracy: {acc_untrained} -> {acc_trained}"
    );
}

#[test]
fn vlm_artifact_trains() {
    let Some(b) = bundle("vlm-tiny-fp") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("vlm-tiny-fp").unwrap();
    let ds = data::build_vlm(&cfg, &b.manifest).unwrap();
    let mut s = Session::new(b);
    s.init(1).unwrap();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for t in 1..=8 {
        let batch = &ds.train[(t - 1) % ds.train.len()];
        s.train_step(batch, &default_ctrl(b, t as f32, 2e-3), &full_plan(b)).unwrap();
        let m = s.probe().unwrap();
        let loss = m[0] / m[1].max(1.0);
        if t == 1 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first, "vlm loss {first} -> {last}");
}

#[test]
fn sgd_artifact_trains() {
    let Some(b) = bundle("lm-tiny-sgd") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("lm-tiny-sgd").unwrap();
    let mut ds = data::build_lm(&cfg, &b.manifest).unwrap();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    opts.total_steps = 30;
    let o = trainer::run(b, &cfg, &opts, || ds.train.next_batch(), &ds.val).unwrap();
    // GradES may legitimately terminate early once everything froze
    assert!(o.steps_run <= 30 && o.steps_run >= 16, "steps {}", o.steps_run);
    let loss = o.log.final_train_loss();
    assert!(loss.is_finite() && loss < 5.6, "sgd loss {loss}");
}

#[test]
fn pipeline_on_off_trajectories_are_bitwise_identical() {
    // Acceptance gate for the pipelined runtime: upload-ahead + prefetch
    // + device-resident validation must not change a single recorded
    // metric or freeze decision for a fixed seed.
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let run_with = |pipeline: PipelineOptions| {
        let mut ds = data::build_lm(&cfg, &b.manifest).unwrap();
        let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
        opts.total_steps = 30;
        opts.pipeline = pipeline;
        trainer::run(b, &cfg, &opts, || ds.train.next_batch(), &ds.val).unwrap()
    };
    let off = run_with(PipelineOptions::off());
    let on = run_with(PipelineOptions::default());
    assert_eq!(off.steps_run, on.steps_run);
    assert_eq!(off.stop_cause, on.stop_cause);
    assert_eq!(off.final_val_loss.to_bits(), on.final_val_loss.to_bits());
    assert_eq!(off.log.records.len(), on.log.records.len());
    for (a, c) in off.log.records.iter().zip(&on.log.records) {
        assert_eq!(a.step, c.step);
        assert_eq!(a.loss.to_bits(), c.loss.to_bits(), "loss diverges at step {}", a.step);
        assert_eq!(a.gdiff, c.gdiff, "gdiff diverges at step {}", a.step);
    }
    assert_eq!(off.log.val_points.len(), on.log.val_points.len());
    for ((s1, v1), (s2, v2)) in off.log.val_points.iter().zip(&on.log.val_points) {
        assert_eq!(s1, s2);
        assert_eq!(v1.to_bits(), v2.to_bits());
    }
    assert_eq!(off.freeze.events.len(), on.freeze.events.len());
    for (e1, e2) in off.freeze.events.iter().zip(&on.freeze.events) {
        assert_eq!((e1.step, e1.component, e1.frozen), (e2.step, e2.component, e2.frozen));
    }
    // and the pipelined run actually overlapped its uploads
    assert!(on.timings.staged_uploads > 0);
    assert_eq!(off.timings.staged_uploads, 0);
}

#[test]
fn async_eval_staleness_zero_is_bitwise_identical_to_synchronous() {
    // Acceptance gate for the async-eval runtime: with `--staleness 0`
    // every chunked pass drains at its issue step, and the trajectory —
    // steps, stop cause, every validation point — must match the
    // synchronous trainer bitwise. Overlapped runs must produce the same
    // val-loss *series* (snapshots pin the check step's parameters);
    // only the application step may shift.
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let run_with = |async_eval: AsyncEvalOptions| {
        let mut ds = data::build_lm(&cfg, &b.manifest).unwrap();
        let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::ClassicEs);
        opts.total_steps = 30;
        opts.async_eval = async_eval;
        trainer::run(b, &cfg, &opts, || ds.train.next_batch(), &ds.val).unwrap()
    };
    let sync = run_with(AsyncEvalOptions::synchronous());
    assert!(!sync.log.val_points.is_empty(), "ES checks must fire in 30 steps");
    // chunk size is irrelevant at k = 0: every pass drains at its issue step
    let k0 = run_with(AsyncEvalOptions { chunk: 1, staleness: StalenessBound::sync() });
    assert_eq!(sync.steps_run, k0.steps_run);
    assert_eq!(sync.stop_cause, k0.stop_cause);
    assert_eq!(sync.final_val_loss.to_bits(), k0.final_val_loss.to_bits());
    assert_eq!(sync.log.val_points.len(), k0.log.val_points.len());
    for ((s1, v1), (s2, v2)) in sync.log.val_points.iter().zip(&k0.log.val_points) {
        assert_eq!(s1, s2);
        assert_eq!(v1.to_bits(), v2.to_bits(), "k=0 diverged at check step {s1}");
    }
    assert_eq!(k0.async_eval.issued, k0.async_eval.completed);
    assert_eq!(k0.async_eval.forced_drains, 0);

    let over = run_with(AsyncEvalOptions::overlapped(1, 4));
    assert!(over.async_eval.issued > 0);
    for ((s1, v1), (s2, v2)) in sync.log.val_points.iter().zip(&over.log.val_points) {
        assert_eq!(s1, s2);
        assert_eq!(v1.to_bits(), v2.to_bits(), "overlapped series diverged at check {s1}");
    }
}

#[test]
fn snapshot_eval_matches_current_state_eval() {
    // A snapshot of the current step must score exactly like the live
    // state, and a snapshot pinned *before* further training must keep
    // scoring the old parameters.
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, &b.manifest).unwrap();
    let mut s = Session::new(b);
    s.init(9).unwrap();
    for t in 1..=4 {
        let batch = ds.train.next_batch();
        s.train_step(&batch, &default_ctrl(b, t as f32, 1e-3), &full_plan(b)).unwrap();
    }
    let cache = DeviceBatchCache::upload(&s, &ds.val).unwrap();
    let live = s.eval_mean_loss_cached(&cache).unwrap();
    let snap = s.snapshot().unwrap();
    let (mut ls, mut cs) = (0.0, 0.0);
    for i in 0..cache.len() {
        // the trainer's chunk path, driven manually via the public API
        let io = s.upload_batch(&ds.val[i]).unwrap();
        let (l, c) = s.eval_batch_snapshot(&snap, &io).unwrap();
        ls += l;
        cs += c;
    }
    assert_eq!((ls / cs).to_bits(), live.to_bits(), "snapshot == live state at pin time");
    // advance training; the pinned snapshot must not move
    for t in 5..=8 {
        let batch = ds.train.next_batch();
        s.train_step(&batch, &default_ctrl(b, t as f32, 1e-3), &full_plan(b)).unwrap();
    }
    let io = s.upload_batch(&ds.val[0]).unwrap();
    let (l_snap, _) = s.eval_batch_snapshot(&snap, &io).unwrap();
    let (l_live, _) = s.eval_batch_uploaded(&io).unwrap();
    let (l_snap2, _) = s.eval_batch_snapshot(&snap, &io).unwrap();
    assert_eq!(l_snap.to_bits(), l_snap2.to_bits(), "snapshot eval is stable");
    assert_ne!(l_snap.to_bits(), l_live.to_bits(), "training moved the live state");
    // host round trip: rehydrated snapshots score identically
    let rehydrated =
        s.upload_snapshot(&s.snapshot_to_host(&snap).unwrap(), snap.step).unwrap();
    let (l_re, _) = s.eval_batch_snapshot(&rehydrated, &io).unwrap();
    assert_eq!(l_snap.to_bits(), l_re.to_bits());
}

#[test]
fn prefetched_source_matches_inline_closure() {
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    opts.total_steps = 25;

    let mut ds1 = data::build_lm(&cfg, &b.manifest).unwrap();
    let inline = trainer::run(b, &cfg, &opts, || ds1.train.next_batch(), &ds1.val).unwrap();

    let ds2 = data::build_lm(&cfg, &b.manifest).unwrap();
    let mut source = Prefetcher::spawn(ds2.train, 2);
    let pre = trainer::run_source(b, &cfg, &opts, &mut source, &ds2.val).unwrap();

    assert_eq!(inline.steps_run, pre.steps_run);
    assert_eq!(
        inline.log.final_train_loss().to_bits(),
        pre.log.final_train_loss().to_bits()
    );
    assert_eq!(inline.final_val_loss.to_bits(), pre.final_val_loss.to_bits());
}

#[test]
fn device_cached_eval_matches_upload_per_call() {
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, &b.manifest).unwrap();
    let mut s = Session::new(b);
    s.init(21).unwrap();
    for t in 1..=5 {
        let batch = ds.train.next_batch();
        s.train_step(&batch, &default_ctrl(b, t as f32, 1e-3), &full_plan(b)).unwrap();
    }
    let uncached = s.eval_mean_loss(&ds.val).unwrap();
    let cache = DeviceBatchCache::upload(&s, &ds.val).unwrap();
    assert_eq!(cache.len(), ds.val.len());
    // repeated cached passes: all identical to the uncached value (same
    // executable, same data; only the upload disappears)
    for _ in 0..3 {
        let cached = s.eval_mean_loss_cached(&cache).unwrap();
        assert_eq!(uncached.to_bits(), cached.to_bits());
    }
    // per-row path equality too (the harness's cached scoring)
    let io = s.upload_batch(&ds.val[0]).unwrap();
    assert_eq!(s.eval_rows(&ds.val[0]).unwrap(), s.eval_rows_uploaded(&io).unwrap());
}

#[test]
fn parallel_bundle_load_matches_sequential() {
    if bundle("lm-tiny-fp").is_none() {
        return; // env gate / artifacts missing
    }
    let dir = grades::config::repo_root().join("artifacts").join("lm-tiny-fp");
    CLIENT.with(|c| {
        let seq = Bundle::load_with(c, &dir, false).unwrap();
        let par = Bundle::load_with(c, &dir, true).unwrap();
        let mut s1 = Session::new(&seq);
        let mut s2 = Session::new(&par);
        s1.init(17).unwrap();
        s2.init(17).unwrap();
        assert_eq!(s1.state_to_host().unwrap(), s2.state_to_host().unwrap());
        let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
        let mut ds = data::build_lm(&cfg, &seq.manifest).unwrap();
        let batch = ds.train.next_batch();
        s1.train_step(&batch, &default_ctrl(&seq, 1.0, 1e-3), &full_plan(&seq)).unwrap();
        s2.train_step(&batch, &default_ctrl(&par, 1.0, 1e-3), &full_plan(&par)).unwrap();
        assert_eq!(s1.state_to_host().unwrap(), s2.state_to_host().unwrap());
    });
}

#[test]
fn runs_are_reproducible() {
    let Some(b) = bundle("lm-tiny-fp") else { return };
    let b = &*b;
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut go = || {
        let mut ds = data::build_lm(&cfg, &b.manifest).unwrap();
        let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
        opts.total_steps = 25;
        let o = trainer::run(b, &cfg, &opts, || ds.train.next_batch(), &ds.val).unwrap();
        (o.log.final_train_loss(), o.final_val_loss)
    };
    assert_eq!(go(), go());
}
