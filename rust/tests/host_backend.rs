//! Full GradES training trajectories in tier-1 — no Python toolchain, no
//! compiled artifacts, no PJRT.
//!
//! These are the host-backend ports of the `GRADES_ARTIFACTS=1` trainer
//! tests in `rust/tests/integration.rs`: init determinism, train-step
//! semantics, freeze-mask behaviour, the three stopping methods
//! (freezing decisions included), pipelined-runtime equivalence, async
//! evaluation, checkpointing, warm starts and MC scoring — all running
//! on every `cargo test -q`. The XLA variants stay env-gated in
//! `integration.rs`; cross-backend agreement is asserted by
//! `rust/tests/differential.rs`.
//!
//! Also here: the golden-trajectory fixtures under `artifacts/golden/`.
//! Every run asserts bitwise self-reproducibility; when a fixture file is
//! checked in it is additionally asserted bitwise (catching accidental
//! trajectory drift the way PR 1–3's equivalence asserts did).
//! Regenerate with `GRADES_WRITE_GOLDEN=1 cargo test -q --test
//! host_backend golden` after an *intentional* trajectory change.

use grades::config::RepoConfig;
use grades::coordinator::scheduler::StepPlan;
use grades::coordinator::trainer::{self, StopCause, StoppingMethod, TrainerOptions};
use grades::coordinator::warmstart::BaseCheckpoint;
use grades::data;
use grades::eval::{benchmarks, harness};
use grades::runtime::async_eval::{AsyncEvalOptions, StalenessBound};
use grades::runtime::backend::Backend;
use grades::runtime::host_backend::HostBackend;
use grades::runtime::pipeline::{DeviceBatchCache, PipelineOptions, Prefetcher};
use grades::runtime::session::Session;

fn backend(config: &str) -> HostBackend {
    let cfg = RepoConfig::by_name(config).expect("config");
    HostBackend::for_config(&cfg).expect("host backend")
}

fn full_plan(b: &dyn Backend) -> StepPlan {
    StepPlan::all_active(b.manifest().n_components)
}

fn default_ctrl(b: &dyn Backend, t: f32, lr: f32) -> Vec<f32> {
    let m = b.manifest();
    let mut ctrl = vec![0f32; m.ctrl_len];
    ctrl[0] = t;
    ctrl[1] = lr;
    ctrl[2] = 1.0;
    for c in ctrl.iter_mut().skip(m.ctrl_mask_offset) {
        *c = 1.0;
    }
    ctrl
}

#[test]
fn init_is_deterministic_per_seed() {
    let b = backend("lm-tiny-fp");
    let mut s1 = Session::new(&b);
    let mut s2 = Session::new(&b);
    s1.init(7).unwrap();
    s2.init(7).unwrap();
    assert_eq!(s1.state_to_host().unwrap(), s2.state_to_host().unwrap());
    s2.init(8).unwrap();
    assert_ne!(s1.state_to_host().unwrap(), s2.state_to_host().unwrap());
}

#[test]
fn train_step_reduces_loss_on_repeated_batch() {
    let b = backend("lm-tiny-fp");
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
    let batch = ds.train.next_batch();
    let mut s = Session::new(&b);
    s.init(3).unwrap();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for t in 1..=10 {
        s.train_step(&batch, &default_ctrl(&b, t as f32, 3e-3), &full_plan(&b)).unwrap();
        let m = s.probe().unwrap();
        let loss = m[0] / m[1].max(1.0);
        if t == 1 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first - 0.5, "loss {first} -> {last}");
}

#[test]
fn freeze_mask_freezes_component_params() {
    let b = backend("lm-tiny-fp");
    let m = b.manifest();
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, m).unwrap();
    let batch = ds.train.next_batch();
    let mut s = Session::new(&b);
    s.init(3).unwrap();
    let before = s.state_to_host().unwrap();
    let mut ctrl = default_ctrl(&b, 1.0, 1e-3);
    ctrl[m.ctrl_mask_offset] = 0.0; // freeze component 0
    s.train_step(&batch, &ctrl, &full_plan(&b)).unwrap();
    let after = s.state_to_host().unwrap();
    let comp = &m.components[0];
    for tname in &comp.tensors {
        let p = m.param(tname).unwrap();
        assert_eq!(
            before[p.offset..p.offset + p.size()],
            after[p.offset..p.offset + p.size()],
            "frozen tensor {tname} moved"
        );
    }
    let other = &m.components[1].tensors[0];
    let p = m.param(other).unwrap();
    assert_ne!(before[p.offset..p.offset + p.size()], after[p.offset..p.offset + p.size()]);
}

#[test]
fn checkpoint_roundtrip_preserves_state() {
    let b = backend("lm-tiny-fp");
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
    let mut s = Session::new(&b);
    s.init(9).unwrap();
    for t in 1..=3 {
        let batch = ds.train.next_batch();
        s.train_step(&batch, &default_ctrl(&b, t as f32, 1e-3), &full_plan(&b)).unwrap();
    }
    let host = s.state_to_host().unwrap();
    let path = std::env::temp_dir().join("grades_host_ckpt.bin");
    s.save_checkpoint(&path).unwrap();
    let mut s2 = Session::new(&b);
    s2.load_checkpoint(&path).unwrap();
    assert_eq!(s2.state_to_host().unwrap(), host);
    assert_eq!(s2.step, 3);
}

#[test]
fn warm_start_transfers_base_params() {
    let b = backend("lm-tiny-fp");
    let mut s = Session::new(&b);
    s.init(11).unwrap();
    let ck = BaseCheckpoint::from_state(b.manifest(), &s.state_to_host().unwrap()).unwrap();
    let mut s2 = Session::new(&b);
    s2.init(12).unwrap();
    let applied = ck.apply(&mut s2).unwrap();
    assert_eq!(applied, b.manifest().params.len());
    let host = s2.state_to_host().unwrap();
    let w = b.manifest().param("lang.0.attn.q").unwrap();
    assert_eq!(ck.params["lang.0.attn.q"], host[w.offset..w.offset + w.size()].to_vec());
}

#[test]
fn trainer_grades_freezes_and_terminates_early() {
    // τ = ∞-like: every component converges at the first post-grace
    // probe, so Alg. 1 terminates right after ⌈αT⌉ — the full freeze +
    // termination path with a deterministic stopping step.
    let b = backend("lm-tiny-fp");
    let mut cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    cfg.grades.alpha = 0.2;
    cfg.grades.tau = 1e9;
    let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    opts.total_steps = 25;
    let o = trainer::run(&b, &cfg, &opts, || ds.train.next_batch(), &ds.val[..2.min(ds.val.len())])
        .unwrap();
    assert_eq!(o.stop_cause, StopCause::AllComponentsFrozen);
    assert_eq!(o.steps_run, 6, "all components freeze at grace+1 = 6");
    assert!(o.freeze.all_frozen());
    assert_eq!(o.freeze.events.len(), b.manifest().n_components);
    // savings come from termination: spent << full-budget dense cost
    let full_budget =
        grades::coordinator::flops::FlopsCounter::dense_step(b.manifest()) * 25.0;
    assert!(o.flops.total() < full_budget * 0.75);
}

#[test]
fn trainer_classic_es_runs_validation() {
    let b = backend("lm-tiny-fp");
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
    let val = &ds.val[..3.min(ds.val.len())];
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::ClassicEs);
    opts.total_steps = 12;
    let o = trainer::run(&b, &cfg, &opts, || ds.train.next_batch(), val).unwrap();
    assert!(o.validation_secs > 0.0);
    assert!(!o.log.val_points.is_empty());
    assert!(o.flops.validation > 0.0);
    assert!(o.final_val_loss.is_finite());
}

#[test]
fn trainer_sgd_config_trains() {
    let b = backend("lm-tiny-sgd");
    let cfg = RepoConfig::by_name("lm-tiny-sgd").unwrap();
    let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    opts.total_steps = 10;
    opts.final_validation = false;
    let o = trainer::run(&b, &cfg, &opts, || ds.train.next_batch(), &[]).unwrap();
    assert!(o.steps_run >= 5 && o.steps_run <= 10);
    let loss = o.log.final_train_loss();
    assert!(loss.is_finite() && loss < 7.0, "sgd loss {loss}");
}

#[test]
fn pipeline_on_off_trajectories_are_bitwise_identical() {
    // The pipelined-runtime acceptance gate, now running in tier-1:
    // upload-ahead + prefetch + cached validation must not change a
    // single recorded metric or freeze decision for a fixed seed.
    let b = backend("lm-tiny-fp");
    let mut cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    cfg.grades.alpha = 0.3;
    let run_with = |pipeline: PipelineOptions| {
        let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
        let val: Vec<_> = ds.val.iter().take(2).cloned().collect();
        let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
        opts.total_steps = 10;
        opts.pipeline = pipeline;
        trainer::run(&b, &cfg, &opts, || ds.train.next_batch(), &val).unwrap()
    };
    let off = run_with(PipelineOptions::off());
    let on = run_with(PipelineOptions::default());
    assert_eq!(off.steps_run, on.steps_run);
    assert_eq!(off.stop_cause, on.stop_cause);
    assert_eq!(off.final_val_loss.to_bits(), on.final_val_loss.to_bits());
    assert_eq!(off.log.records.len(), on.log.records.len());
    for (a, c) in off.log.records.iter().zip(&on.log.records) {
        assert_eq!(a.step, c.step);
        assert_eq!(a.loss.to_bits(), c.loss.to_bits(), "loss diverges at step {}", a.step);
        assert_eq!(a.gdiff, c.gdiff, "gdiff diverges at step {}", a.step);
    }
    assert_eq!(off.freeze.events.len(), on.freeze.events.len());
    for (e1, e2) in off.freeze.events.iter().zip(&on.freeze.events) {
        assert_eq!((e1.step, e1.component, e1.frozen), (e2.step, e2.component, e2.frozen));
    }
    // and the pipelined run actually overlapped its uploads
    assert!(on.timings.staged_uploads > 0);
    assert_eq!(off.timings.staged_uploads, 0);
}

#[test]
fn async_eval_staleness_zero_is_bitwise_identical_to_synchronous() {
    let b = backend("lm-tiny-fp");
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let run_with = |async_eval: AsyncEvalOptions| {
        let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
        let val: Vec<_> = ds.val.iter().take(2).cloned().collect();
        let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::ClassicEs);
        opts.total_steps = 8;
        opts.async_eval = async_eval;
        trainer::run(&b, &cfg, &opts, || ds.train.next_batch(), &val).unwrap()
    };
    let sync = run_with(AsyncEvalOptions::synchronous());
    assert!(!sync.log.val_points.is_empty(), "ES checks must fire in 8 steps");
    let k0 = run_with(AsyncEvalOptions { chunk: 1, staleness: StalenessBound::sync() });
    assert_eq!(sync.steps_run, k0.steps_run);
    assert_eq!(sync.stop_cause, k0.stop_cause);
    assert_eq!(sync.final_val_loss.to_bits(), k0.final_val_loss.to_bits());
    assert_eq!(sync.log.val_points.len(), k0.log.val_points.len());
    for ((s1, v1), (s2, v2)) in sync.log.val_points.iter().zip(&k0.log.val_points) {
        assert_eq!(s1, s2);
        assert_eq!(v1.to_bits(), v2.to_bits(), "k=0 diverged at check step {s1}");
    }
    let over = run_with(AsyncEvalOptions::overlapped(1, 4));
    assert!(over.async_eval.issued > 0);
    for ((s1, v1), (s2, v2)) in sync.log.val_points.iter().zip(&over.log.val_points) {
        assert_eq!(s1, s2);
        assert_eq!(v1.to_bits(), v2.to_bits(), "overlapped series diverged at check {s1}");
    }
}

#[test]
fn snapshot_eval_matches_current_state_eval() {
    let b = backend("lm-tiny-fp");
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
    let mut s = Session::new(&b);
    s.init(9).unwrap();
    for t in 1..=3 {
        let batch = ds.train.next_batch();
        s.train_step(&batch, &default_ctrl(&b, t as f32, 1e-3), &full_plan(&b)).unwrap();
    }
    let val: Vec<_> = ds.val.iter().take(2).cloned().collect();
    let cache = DeviceBatchCache::upload(&s, &val).unwrap();
    let live = s.eval_mean_loss_cached(&cache).unwrap();
    let snap = s.snapshot().unwrap();
    let (mut ls, mut cs) = (0.0, 0.0);
    for i in 0..cache.len() {
        let io = s.upload_batch(&val[i]).unwrap();
        let (l, c) = s.eval_batch_snapshot(&snap, &io).unwrap();
        ls += l;
        cs += c;
    }
    assert_eq!((ls / cs).to_bits(), live.to_bits(), "snapshot == live state at pin time");
    // advance training; the pinned snapshot must not move
    for t in 4..=5 {
        let batch = ds.train.next_batch();
        s.train_step(&batch, &default_ctrl(&b, t as f32, 1e-3), &full_plan(&b)).unwrap();
    }
    let io = s.upload_batch(&val[0]).unwrap();
    let (l_snap, _) = s.eval_batch_snapshot(&snap, &io).unwrap();
    let (l_live, _) = s.eval_batch_uploaded(&io).unwrap();
    assert_ne!(l_snap.to_bits(), l_live.to_bits(), "training moved the live state");
    // host round trip: rehydrated snapshots score identically
    let rehydrated =
        s.upload_snapshot(&s.snapshot_to_host(&snap).unwrap(), snap.step).unwrap();
    let (l_re, _) = s.eval_batch_snapshot(&rehydrated, &io).unwrap();
    assert_eq!(l_snap.to_bits(), l_re.to_bits());
}

#[test]
fn prefetched_source_matches_inline_closure() {
    let b = backend("lm-tiny-fp");
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    opts.total_steps = 6;
    opts.final_validation = false;

    let mut ds1 = data::build_lm(&cfg, b.manifest()).unwrap();
    let inline = trainer::run(&b, &cfg, &opts, || ds1.train.next_batch(), &[]).unwrap();

    let ds2 = data::build_lm(&cfg, b.manifest()).unwrap();
    let mut source = Prefetcher::spawn(ds2.train, 2);
    let pre = trainer::run_source(&b, &cfg, &opts, &mut source, &[]).unwrap();

    assert_eq!(inline.steps_run, pre.steps_run);
    assert_eq!(inline.log.final_train_loss().to_bits(), pre.log.final_train_loss().to_bits());
}

#[test]
fn mc_scoring_runs_on_the_host_backend() {
    // The eval_rows → argmin harness end to end (packed + device-cached
    // paths agree); accuracy of an untrained model is sane, not NaN.
    let b = backend("lm-tiny-fp");
    let vocab = grades::data::vocab::Vocab::build(b.manifest().vocab_size).unwrap();
    let suites = benchmarks::lm_suites(&vocab, 0x77, 8);
    let mut s = Session::new(&b);
    s.init(13).unwrap();
    let packed = harness::PackedSuite::pack(b.manifest(), &suites[0]).unwrap();
    let acc = packed.score(&s).unwrap();
    assert!((0.0..=100.0).contains(&acc), "accuracy {acc}");
    let dev = packed.upload(&s).unwrap();
    let acc_dev = dev.score(&s).unwrap();
    assert_eq!(acc.to_bits(), acc_dev.to_bits(), "cached and uncached scoring agree");
}

#[test]
fn runs_are_reproducible() {
    let b = backend("lm-tiny-fp");
    let cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    let mut go = || {
        let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
        let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
        opts.total_steps = 6;
        opts.final_validation = false;
        let o = trainer::run(&b, &cfg, &opts, || ds.train.next_batch(), &[]).unwrap();
        o.log.final_train_loss().to_bits()
    };
    assert_eq!(go(), go());
}

#[test]
fn planned_and_unplanned_grades_trajectories_agree() {
    // The freeze-aware planning gate, host side: per-matrix dW elision
    // must not change anything the trajectory can see — losses, freeze
    // events, step counts, final validation — because a sound plan only
    // skips work whose masked result is a bit-exact no-op. (Omitted
    // components' *logged* gdiff/gabs legitimately differ: the planned
    // run reports 0 where the dense run still measures them.)
    let b = backend("lm-tiny-fp");
    let mut cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    // staggered freezing: generous-but-finite τ after a short grace
    cfg.grades.alpha = 0.25;
    cfg.grades.tau = 0.05;
    let run_with = |elide: bool| {
        let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
        let val: Vec<_> = ds.val.iter().take(2).cloned().collect();
        let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
        opts.total_steps = 14;
        opts.probe_every = 1;
        opts.elide_frozen = elide;
        trainer::run(&b, &cfg, &opts, || ds.train.next_batch(), &val).unwrap()
    };
    let dense = run_with(false);
    let planned = run_with(true);
    assert_eq!(dense.steps_run, planned.steps_run);
    assert_eq!(dense.stop_cause, planned.stop_cause);
    assert_eq!(dense.final_val_loss.to_bits(), planned.final_val_loss.to_bits());
    for (a, c) in dense.log.records.iter().zip(&planned.log.records) {
        assert_eq!(a.step, c.step);
        assert_eq!(a.loss.to_bits(), c.loss.to_bits(), "loss diverged at step {}", a.step);
    }
    assert_eq!(dense.freeze.events.len(), planned.freeze.events.len());
    for (e1, e2) in dense.freeze.events.iter().zip(&planned.freeze.events) {
        assert_eq!((e1.step, e1.component, e1.frozen), (e2.step, e2.component, e2.frozen));
    }
    // the dense run planned nothing; the planned run elided something
    // once components froze, and accounting noticed on both ledgers
    assert_eq!(dense.plan.elided_steps, 0);
    assert_eq!(dense.timings.dw_elided, 0);
    if planned.freeze.n_frozen() > 0 && planned.freeze.events[0].step < planned.steps_run {
        assert!(planned.plan.elided_steps > 0, "froze components but never elided");
        assert!(planned.timings.dw_elided > 0);
        assert!(
            planned.flops.realized_spent < planned.flops.dense_equivalent,
            "realized ledger shows no savings"
        );
        // host lowering is exact: both ledgers agree
        assert_eq!(
            planned.flops.spent.to_bits(),
            planned.flops.realized_spent.to_bits(),
            "host engine must realize the full plan"
        );
    }
    // the dense run realizes nothing: its realized ledger prices every
    // step dense while the theoretical one still credits frozen dW
    if dense.freeze.n_frozen() > 0 && dense.freeze.events[0].step < dense.steps_run {
        assert!(dense.flops.realized_spent > dense.flops.spent);
    }
}

#[test]
fn all_active_plan_is_bitwise_identical_to_planner_off() {
    // A GradES run where τ=0 never freezes anything: every derived plan
    // is all-active, and the planned path must be bitwise identical to
    // the planner-off (pre-refactor dense) path — including the logged
    // per-component statistics, which only diverge for omitted
    // components.
    let b = backend("lm-tiny-fp");
    let mut cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
    cfg.grades.tau = 0.0;
    let run_with = |elide: bool| {
        let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
        let val: Vec<_> = ds.val.iter().take(2).cloned().collect();
        let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
        opts.total_steps = 8;
        opts.probe_every = 1;
        opts.elide_frozen = elide;
        trainer::run(&b, &cfg, &opts, || ds.train.next_batch(), &val).unwrap()
    };
    let off = run_with(false);
    let on = run_with(true);
    assert_eq!(off.steps_run, on.steps_run);
    assert_eq!(off.final_val_loss.to_bits(), on.final_val_loss.to_bits());
    for (a, c) in off.log.records.iter().zip(&on.log.records) {
        assert_eq!(a.loss.to_bits(), c.loss.to_bits());
        assert_eq!(a.gdiff, c.gdiff, "gdiff diverged at step {}", a.step);
        assert_eq!(a.gabs, c.gabs, "gabs diverged at step {}", a.step);
    }
    assert_eq!(on.plan.elided_steps, 0);
    assert_eq!(on.timings.dw_elided, 0);
}

// ---------------------------------------------------------------------------
// Golden trajectory fixtures
// ---------------------------------------------------------------------------

/// Render a compact, bit-exact trace of one trajectory: per-step loss /
/// gnorm / gdiff bits, frozen fraction, freeze events, final val loss.
fn trace_of(o: &grades::coordinator::trainer::TrainOutcome) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for r in &o.log.records {
        write!(out, "step={} loss={:016x} gnorm={:016x} frozen={:.4} gdiff=", r.step,
               r.loss.to_bits(), r.global_gnorm.to_bits(), r.frozen_fraction)
            .unwrap();
        for (i, g) in r.gdiff.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write!(out, "{:08x}", g.to_bits()).unwrap();
        }
        out.push('\n');
    }
    for e in &o.freeze.events {
        writeln!(out, "event step={} comp={} frozen={}", e.step, e.component, e.frozen).unwrap();
    }
    writeln!(out, "steps_run={} stop={:?}", o.steps_run, o.stop_cause).unwrap();
    writeln!(out, "final_val={:016x}", o.final_val_loss.to_bits()).unwrap();
    out
}

fn golden_trajectory(config: &str) -> String {
    let b = backend(config);
    let mut cfg = RepoConfig::by_name(config).unwrap();
    // fixed golden settings, independent of the config file's own τ/α so
    // config tweaks don't silently invalidate fixtures (the tower
    // overrides too: one τ for every component)
    cfg.grades.alpha = 0.25;
    cfg.grades.tau = 0.05;
    cfg.grades.tau_vision = f64::NAN;
    cfg.grades.tau_language = f64::NAN;
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    opts.total_steps = 12;
    opts.probe_every = 1;
    let o = if b.manifest().is_vlm() {
        let ds = data::build_vlm(&cfg, b.manifest()).unwrap();
        let val: Vec<_> = ds.val.iter().take(2).cloned().collect();
        let train = ds.train;
        let mut i = 0usize;
        let next = || {
            let batch = train[i % train.len()].clone();
            i += 1;
            batch
        };
        trainer::run(&b, &cfg, &opts, next, &val).unwrap()
    } else {
        let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
        let val: Vec<_> = ds.val.iter().take(2).cloned().collect();
        trainer::run(&b, &cfg, &opts, || ds.train.next_batch(), &val).unwrap()
    };
    trace_of(&o)
}

fn check_golden(config: &str) {
    let trace = golden_trajectory(config);
    // determinism first: the same trajectory twice, bitwise
    assert_eq!(trace, golden_trajectory(config), "{config}: trajectory not deterministic");
    let path = grades::config::repo_root()
        .join("artifacts")
        .join("golden")
        .join(format!("{config}_grades12.trace"));
    if std::env::var("GRADES_WRITE_GOLDEN").map_or(false, |v| v == "1") {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &trace).unwrap();
        eprintln!("golden: wrote {}", path.display());
        return;
    }
    match std::fs::read_to_string(&path) {
        Ok(want) => assert_eq!(
            trace, want,
            "{config}: trajectory drifted from the checked-in golden fixture \
             {path:?}. If the change is intentional, regenerate with \
             GRADES_WRITE_GOLDEN=1 cargo test --test host_backend golden"
        ),
        Err(_) => eprintln!(
            "golden: no fixture at {} (determinism still asserted); generate one \
             with GRADES_WRITE_GOLDEN=1 on this platform",
            path.display()
        ),
    }
}

#[test]
fn arena_on_off_trajectories_are_bitwise_identical() {
    // The workspace arena only changes where bytes live, never a single
    // arithmetic op — a full GradES trajectory (losses, gnorm, gdiff
    // bits, freeze events, final val) must not move by a bit. Toggling
    // the process-global override mid-suite is safe for the tests
    // running concurrently for exactly the same reason.
    use grades::runtime::host_arena;
    host_arena::set_arena_override(Some(true));
    let on = golden_trajectory("lm-tiny-fp");
    host_arena::set_arena_override(Some(false));
    let off = golden_trajectory("lm-tiny-fp");
    host_arena::set_arena_override(None);
    assert_eq!(on, off, "arena on/off changed the trajectory");
}

#[test]
fn golden_trajectory_lm_tiny_fp() {
    check_golden("lm-tiny-fp");
}

#[test]
fn golden_trajectory_lm_tiny_sgd() {
    check_golden("lm-tiny-sgd");
}

#[test]
fn golden_trajectory_lm_tiny_lora() {
    check_golden("lm-tiny-lora");
}

#[test]
fn golden_trajectory_vlm_tiny_fp() {
    check_golden("vlm-tiny-fp");
}

// ---------------------------------------------------------------------------
// LoRA + VLM trajectory ports
// ---------------------------------------------------------------------------

#[test]
fn trainer_grades_lora_trajectory_freezes_adapters_and_holds_base() {
    // A full GradES fine-tune on the LoRA layout: Eq. 1 statistics come
    // from the adapter pairs, the freeze walk covers all 14 components,
    // and the frozen base weights end the run bit-identical to init.
    let b = backend("lm-tiny-lora");
    let mut cfg = RepoConfig::by_name("lm-tiny-lora").unwrap();
    cfg.grades.alpha = 0.2;
    cfg.grades.tau = 1e9; // every component converges at the first probe
    let mut ds = data::build_lm(&cfg, b.manifest()).unwrap();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    opts.total_steps = 25;
    opts.final_validation = false;
    let trained =
        trainer::run_and_keep(&b, &cfg, &opts, || ds.train.next_batch(), &[]).unwrap();
    let o = &trained.outcome;
    assert_eq!(o.stop_cause, StopCause::AllComponentsFrozen);
    assert!(o.freeze.all_frozen());
    assert_eq!(o.freeze.events.len(), b.manifest().n_components);
    assert!(o.log.final_train_loss().is_finite());
    // the frozen base never moves: bit-identical to the seed init
    let init = b.state_to_host(&b.init_state(opts.seed).unwrap()).unwrap();
    let after = trained.session.state_to_host().unwrap();
    for p in b.manifest().params.iter().filter(|p| !p.trainable) {
        assert_eq!(
            init[p.offset..p.offset + p.size()],
            after[p.offset..p.offset + p.size()],
            "frozen base weight {} moved during a LoRA run",
            p.name
        );
    }
    // while the adapters did train before their freeze step
    let a0 = b.manifest().param(&b.manifest().components[0].tensors[0]).unwrap();
    assert_ne!(
        init[a0.offset..a0.offset + a0.size()],
        after[a0.offset..a0.offset + a0.size()],
        "adapter {} never moved",
        a0.name
    );
}

#[test]
fn trainer_grades_vlm_trajectory_freezes_both_towers() {
    // End-to-end GradES on the two-tower VLM: scene batches (patches
    // included), 28 per-tower components in the freeze walk, and the
    // same early-termination shape as the LM run.
    let b = backend("vlm-tiny-fp");
    let mut cfg = RepoConfig::by_name("vlm-tiny-fp").unwrap();
    cfg.grades.alpha = 0.2;
    cfg.grades.tau = 1e9;
    cfg.grades.tau_vision = f64::NAN;
    cfg.grades.tau_language = f64::NAN;
    let ds = data::build_vlm(&cfg, b.manifest()).unwrap();
    let train = ds.train;
    let mut i = 0usize;
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    opts.total_steps = 25;
    opts.final_validation = false;
    let next = || {
        let batch = train[i % train.len()].clone();
        i += 1;
        batch
    };
    let o = trainer::run(&b, &cfg, &opts, next, &[]).unwrap();
    assert_eq!(o.stop_cause, StopCause::AllComponentsFrozen);
    assert!(o.freeze.all_frozen());
    assert_eq!(o.freeze.events.len(), 28);
    assert!(o.log.final_train_loss().is_finite());
    // both towers appear among the frozen components
    let m = b.manifest();
    for tower in ["vision", "language"] {
        assert!(
            o.freeze.events.iter().any(|e| m.components[e.component].tower == tower),
            "no freeze event from the {tower} tower"
        );
    }
}

#[test]
fn vlm_planned_and_dense_grades_trajectories_agree() {
    // The freeze-aware elision gate on the VLM layout: per-matrix dW
    // elision across both towers must leave every loss bit and freeze
    // decision unchanged.
    let b = backend("vlm-tiny-fp");
    let mut cfg = RepoConfig::by_name("vlm-tiny-fp").unwrap();
    cfg.grades.alpha = 0.25;
    cfg.grades.tau = 0.05;
    cfg.grades.tau_vision = f64::NAN;
    cfg.grades.tau_language = f64::NAN;
    let run_with = |elide: bool| {
        let ds = data::build_vlm(&cfg, b.manifest()).unwrap();
        let val: Vec<_> = ds.val.iter().take(2).cloned().collect();
        let train = ds.train;
        let mut i = 0usize;
        let next = || {
            let batch = train[i % train.len()].clone();
            i += 1;
            batch
        };
        let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
        opts.total_steps = 10;
        opts.probe_every = 1;
        opts.elide_frozen = elide;
        trainer::run(&b, &cfg, &opts, next, &val).unwrap()
    };
    let dense = run_with(false);
    let planned = run_with(true);
    assert_eq!(dense.steps_run, planned.steps_run);
    assert_eq!(dense.stop_cause, planned.stop_cause);
    assert_eq!(dense.final_val_loss.to_bits(), planned.final_val_loss.to_bits());
    for (a, c) in dense.log.records.iter().zip(&planned.log.records) {
        assert_eq!(a.loss.to_bits(), c.loss.to_bits(), "loss diverged at step {}", a.step);
    }
    assert_eq!(dense.freeze.events.len(), planned.freeze.events.len());
    for (e1, e2) in dense.freeze.events.iter().zip(&planned.freeze.events) {
        assert_eq!((e1.step, e1.component, e1.frozen), (e2.step, e2.component, e2.frozen));
    }
}

#[test]
fn vlm_mc_scoring_runs_on_the_host_backend() {
    // The Table 2/3 harness end to end on the host engine: pack a scene
    // suite against the VLM manifest and score an untrained model.
    let b = backend("vlm-tiny-fp");
    let cfg = RepoConfig::by_name("vlm-tiny-fp").unwrap();
    let ds = data::build_vlm(&cfg, b.manifest()).unwrap();
    let suites = benchmarks::vlm_suites(&ds.scene_cfg, &ds.vocab, 0x33, 6);
    let mut s = Session::new(&b);
    s.init(13).unwrap();
    let packed = harness::PackedSuite::pack(b.manifest(), &suites[0]).unwrap();
    let acc = packed.score(&s).unwrap();
    assert!((0.0..=100.0).contains(&acc), "accuracy {acc}");
}

#[test]
fn lora_warm_start_maps_base_tensors_across_layouts() {
    // The paper's fine-tuning setting: an fp pretrain checkpoint applied
    // to the LoRA layout maps every *base* tensor by name (different
    // offsets) and leaves the fresh adapters alone.
    let fp = backend("lm-tiny-fp");
    let lora = backend("lm-tiny-lora");
    let mut s = Session::new(&fp);
    s.init(11).unwrap();
    let ck = BaseCheckpoint::from_state(fp.manifest(), &s.state_to_host().unwrap()).unwrap();
    let mut s2 = Session::new(&lora);
    s2.init(12).unwrap();
    let fresh = s2.state_to_host().unwrap();
    let applied = ck.apply(&mut s2).unwrap();
    // every fp tensor exists in the lora layout; the 28 adapters don't
    assert_eq!(applied, fp.manifest().params.len());
    let host = s2.state_to_host().unwrap();
    let w = lora.manifest().param("lang.0.attn.q").unwrap();
    assert_eq!(ck.params["lang.0.attn.q"], host[w.offset..w.offset + w.size()].to_vec());
    let a = lora.manifest().param("lang.0.attn.q.lora_a").unwrap();
    assert_eq!(
        fresh[a.offset..a.offset + a.size()],
        host[a.offset..a.offset + a.size()],
        "adapter init must survive the warm start"
    );
}
