//! Scaled-down Tables 6 & 7 (τ × α grid) + design-choice ablations —
//! `cargo bench` twin of `grades repro ablation` — plus the scheduler
//! A/B: the same grid executed sequentially (`--jobs 1`) and on a worker
//! pool (`--jobs 4`) against one warmed runner, verifying the result sets
//! are identical and emitting `BENCH_scheduler.json` (jobs/sec + total
//! wall per mode) for the perf trajectory.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};
use grades::config::repo_root;
use grades::exp::ablation::{self, ALPHAS, TAUS};
use grades::exp::{plan, scheduler, ExpOptions};
use grades::exp::scheduler::JobStatus;
use grades::util::json::{self, Json};
use grades::util::timer::Timer;

const CONC_WORKERS: usize = 4;

/// id → average accuracy for every completed job (the equality check).
fn result_set(
    graph: &plan::JobGraph,
    report: &scheduler::RunReport,
) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    for (i, s) in report.statuses.iter().enumerate() {
        if let JobStatus::Done { result: Some(r), .. } = s {
            let avg = r.accuracies.last().map(|a| a.1).unwrap_or(f64::NAN);
            out.insert(graph.get(i).id.clone(), format!("{avg:.6}"));
        }
    }
    out
}

fn main() -> Result<()> {
    if !repo_root().join("artifacts").join("lm-tiny-fp").join("manifest.json").exists() {
        eprintln!("bench_ablation: artifacts/lm-tiny-fp missing (run `make artifacts`); skipping");
        return Ok(());
    }
    // The rendered-tables twin of `grades repro ablation` (sequential).
    let mut opts = ExpOptions::quick(60, 8);
    opts.out_dir = repo_root().join("results").join("bench");
    opts.verbose = true;
    opts.resume = false;
    ablation::run(&opts, "lm-tiny-fp")?;

    // --- scheduler A/B over the same grid shape ---
    let mut qopts = ExpOptions::quick(40, 8);
    qopts.out_dir = repo_root().join("results").join("bench");
    qopts.verbose = false;
    let runner = scheduler::DeviceRunner::new(&qopts);
    let sopts = |jobs: usize| scheduler::SchedulerOptions {
        jobs,
        manifest_path: None, // no resume: every pass runs every cell
        resume: false,
        ..Default::default()
    };
    // Warm the shared caches (compile, dataset rows, device suites) with
    // one cell so the A/B measures scheduling, not cold start.
    let (warm_graph, _) = plan::ablation_plan("lm-tiny-fp", &TAUS[..1], &ALPHAS[..1])?;
    scheduler::execute(&warm_graph, &sopts(1), &runner)?.require_ok(&warm_graph)?;

    let (graph, _) = plan::ablation_plan("lm-tiny-fp", &TAUS, &ALPHAS)?;
    let n = graph.len() as f64;

    let t = Timer::new();
    let seq = scheduler::execute(&graph, &sopts(1), &runner)?;
    let seq_wall = t.secs();
    seq.require_ok(&graph)?;

    let t = Timer::new();
    let conc = scheduler::execute(&graph, &sopts(CONC_WORKERS), &runner)?;
    let conc_wall = t.secs();
    conc.require_ok(&graph)?;

    // jobs=1 and jobs=N must emit identical accuracy cells.
    let (a, b) = (result_set(&graph, &seq), result_set(&graph, &conc));
    ensure!(a == b, "sequential and concurrent grids diverged: {a:?} vs {b:?}");

    println!(
        "scheduler A/B over {} jobs: seq {:.2}s ({:.2} jobs/s) | {} workers {:.2}s ({:.2} jobs/s) | speedup {:.2}x | tables identical",
        graph.len(),
        seq_wall,
        n / seq_wall,
        CONC_WORKERS,
        conc_wall,
        n / conc_wall,
        seq_wall / conc_wall,
    );

    let mut m = BTreeMap::new();
    m.insert("grid_jobs".to_string(), Json::Num(n));
    m.insert("seq_wall_secs".to_string(), Json::Num(seq_wall));
    m.insert("seq_jobs_per_sec".to_string(), Json::Num(n / seq_wall));
    m.insert("conc_workers".to_string(), Json::Num(CONC_WORKERS as f64));
    m.insert("conc_wall_secs".to_string(), Json::Num(conc_wall));
    m.insert("conc_jobs_per_sec".to_string(), Json::Num(n / conc_wall));
    m.insert("speedup".to_string(), Json::Num(seq_wall / conc_wall));
    m.insert("identical_tables".to_string(), Json::Bool(true));
    let out = repo_root().join("BENCH_scheduler.json");
    std::fs::write(&out, json::write(&Json::Obj(m)))?;
    println!("wrote {}", out.display());
    Ok(())
}
