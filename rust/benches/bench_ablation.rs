//! Scaled-down Tables 6 & 7 (τ × α grid) + design-choice ablations —
//! `cargo bench` twin of `grades repro ablation`.

use anyhow::Result;
use grades::exp::{ablation, ExpOptions};
use grades::runtime::artifact::Client;

fn main() -> Result<()> {
    let client = Client::cpu()?;
    let mut opts = ExpOptions::quick(60, 8);
    opts.out_dir = grades::config::repo_root().join("results").join("bench");
    opts.verbose = true;
    ablation::run(&client, &opts, "lm-tiny-fp")
}
