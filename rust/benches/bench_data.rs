//! Data-substrate throughput: corpus generation, packing, scene rendering,
//! benchmark-suite construction. The data path must never bottleneck the
//! trainer (it runs on the hot loop between steps).

use anyhow::Result;
use grades::data::{batcher, corpus, multimodal, vocab::Vocab};
use grades::eval::benchmarks;
use grades::util::timer::bench;

fn main() -> Result<()> {
    println!("## bench_data\n");
    let v = Vocab::build(4096)?;

    let t = bench(1, 5, || {
        let s = corpus::generate(&v, 1, 2048);
        std::hint::black_box(&s);
    });
    println!("corpus 2048 sentences        {:>9.3} ms  ({:.0} sent/s)", t.p50 * 1e3, 2048.0 / t.p50);

    let sentences = corpus::generate(&v, 1, 2048);
    let t = bench(1, 5, || {
        let rows = batcher::pack_rows(&sentences, 128);
        std::hint::black_box(&rows);
    });
    println!("pack 2048 sentences @T=128   {:>9.3} ms", t.p50 * 1e3);

    let rows = batcher::pack_rows(&sentences, 128);
    let mut it = batcher::BatchIter::new(rows, 8, 3);
    let t = bench(10, 200, || {
        let b = it.next_batch();
        std::hint::black_box(&b);
    });
    println!("next_batch (B=8, T=128)      {:>9.3} ms", t.p50 * 1e3);

    let scfg = multimodal::SceneConfig::for_model(16, 24, &v);
    let t = bench(1, 5, || {
        let ex = multimodal::generate(&scfg, &v, 2, 512);
        std::hint::black_box(&ex);
    });
    println!("512 scenes render+caption    {:>9.3} ms  ({:.0} scenes/s)", t.p50 * 1e3, 512.0 / t.p50);

    let t = bench(1, 3, || {
        let s = benchmarks::lm_suites(&v, 9, 64);
        std::hint::black_box(&s);
    });
    println!("8 LM suites x 64 questions   {:>9.3} ms", t.p50 * 1e3);

    let t = bench(1, 3, || {
        let s = benchmarks::nanovlm_suites(&scfg, &v, 9, 32);
        std::hint::black_box(&s);
    });
    println!("6 VLM suites x 32 questions  {:>9.3} ms", t.p50 * 1e3);
    Ok(())
}
