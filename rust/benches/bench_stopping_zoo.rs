//! Stopping-method zoo head-to-head — every `StoppingMethod` on the same
//! config, forced onto the pure-Rust host backend so the bench runs
//! artifact-free (and therefore in CI). Emits `BENCH_stopping_zoo.json`
//! with per-method wall clock, steps, accuracy and validation passes,
//! asserts the validation-free methods (GradES, EB criterion) really
//! issued **zero** validation passes, and verifies `--jobs 1` and
//! `--jobs 4` render byte-identical zoo tables. `--quick` shortens the
//! runs (CI smoke mode).

use std::collections::BTreeMap;

use anyhow::{ensure, Result};
use grades::config::repo_root;
use grades::coordinator::trainer::{StoppingMethod, ALL_METHODS};
use grades::exp::ablation::{zoo_row, zoo_table_header};
use grades::exp::plan::{EvalKind, JobGraph, JobSpec};
use grades::exp::{scheduler, ExpOptions};
use grades::runtime::backend::BackendChoice;
use grades::util::json::{self, Json};
use grades::util::timer::Timer;

const CONFIG: &str = "lm-tiny-fp";
const CONC_WORKERS: usize = 4;

fn zoo_graph() -> Result<JobGraph> {
    let mut g = JobGraph::new();
    for method in ALL_METHODS {
        g.add(JobSpec::train(
            format!("zoo/{CONFIG}/{}", method.label()),
            CONFIG,
            method,
            EvalKind::LmSuites,
        ))?;
    }
    Ok(g)
}

/// Render the zoo table for a report. With `redact_wall` the wall-clock
/// column is blanked — that form is the byte-identity comparand (every
/// other cell is deterministic on the host backend; wall clock is real
/// time and legitimately differs between runs).
fn render(graph: &JobGraph, report: &scheduler::RunReport, redact_wall: bool) -> Result<String> {
    let mut t = zoo_table_header();
    for id in 0..graph.len() {
        let mut row = zoo_row(CONFIG, report.result(id)?);
        if redact_wall {
            row[2] = "-".to_string();
        }
        t.row(row);
    }
    Ok(t.render())
}

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (steps, questions) = if quick { (40, 8) } else { (120, 16) };
    let mut opts = ExpOptions::quick(steps, questions);
    opts.out_dir = repo_root().join("results").join("bench");
    opts.backend = BackendChoice::Host; // artifact-free by construction
    let runner = scheduler::DeviceRunner::new(&opts);
    let sopts = |jobs: usize| scheduler::SchedulerOptions {
        jobs,
        manifest_path: None, // no resume: every pass runs every method
        resume: false,
        backend: BackendChoice::Host,
        ..Default::default()
    };
    let graph = zoo_graph()?;

    let t = Timer::new();
    let seq = scheduler::execute(&graph, &sopts(1), &runner)?;
    let seq_wall = t.secs();
    seq.require_ok(&graph)?;

    // --- the headline claim: gradient-signal methods never validate ---
    for (id, method) in ALL_METHODS.iter().enumerate() {
        let r = seq.result(id)?;
        let passes = r.outcome.async_eval.issued;
        if matches!(method, StoppingMethod::GradEs | StoppingMethod::EbCriterion) {
            ensure!(
                passes == 0,
                "{} issued {passes} validation passes; expected 0",
                method.label()
            );
        }
    }

    // --- scheduler A/B: jobs=1 and jobs=N tables must be byte-identical ---
    let t = Timer::new();
    let conc = scheduler::execute(&graph, &sopts(CONC_WORKERS), &runner)?;
    let conc_wall = t.secs();
    conc.require_ok(&graph)?;
    let (a, b) = (render(&graph, &seq, true)?, render(&graph, &conc, true)?);
    ensure!(a == b, "jobs=1 and jobs={CONC_WORKERS} zoo tables diverged:\n{a}\nvs\n{b}");

    let shown = render(&graph, &seq, false)?;
    println!(
        "## Stopping-method zoo ({CONFIG}, host backend, {steps} steps)\n\n{shown}\n\
         seq {seq_wall:.2}s | {CONC_WORKERS} workers {conc_wall:.2}s | tables identical"
    );

    let mut methods = Vec::new();
    for (id, method) in ALL_METHODS.iter().enumerate() {
        let r = seq.result(id)?;
        let avg = r.accuracies.last().map(|x| x.1).unwrap_or(f64::NAN);
        let mut m = BTreeMap::new();
        m.insert("method".to_string(), Json::Str(method.label().to_string()));
        m.insert("wall_secs".to_string(), Json::Num(r.outcome.wall_secs));
        m.insert("monitor_secs".to_string(), Json::Num(r.outcome.monitor_secs));
        m.insert("validation_secs".to_string(), Json::Num(r.outcome.validation_secs));
        m.insert("steps_run".to_string(), Json::Num(r.outcome.steps_run as f64));
        m.insert(
            "val_passes".to_string(),
            Json::Num(r.outcome.async_eval.issued as f64),
        );
        m.insert("avg_acc".to_string(), Json::Num(avg));
        m.insert(
            "frozen".to_string(),
            Json::Num(r.outcome.freeze.n_frozen() as f64),
        );
        methods.push(Json::Obj(m));
    }
    let mut top = BTreeMap::new();
    top.insert("config".to_string(), Json::Str(CONFIG.to_string()));
    top.insert("steps".to_string(), Json::Num(steps as f64));
    top.insert("seq_wall_secs".to_string(), Json::Num(seq_wall));
    top.insert("conc_wall_secs".to_string(), Json::Num(conc_wall));
    top.insert("identical_tables".to_string(), Json::Bool(true));
    top.insert("methods".to_string(), Json::Arr(methods));
    let out = repo_root().join("BENCH_stopping_zoo.json");
    std::fs::write(&out, json::write(&Json::Obj(top)))?;
    println!("wrote {}", out.display());
    Ok(())
}
