//! GradES monitoring overhead (paper §7 claims ~3%): identical training
//! loops with the probe+monitor enabled every step vs fully disabled, and
//! the classic-ES validation overhead for contrast (Table 4's "+ES slower
//! than baseline" effect).

use anyhow::Result;
use grades::config::RepoConfig;
use grades::coordinator::trainer::{self, StoppingMethod, TrainerOptions};
use grades::data;
use grades::runtime::artifact::{Bundle, Client};

fn main() -> Result<()> {
    let client = Client::cpu()?;
    let config = "lm-small-fp";
    let cfg = RepoConfig::by_name(config)?;
    let bundle = Bundle::by_name(&client, config)?;
    let steps = 80;

    let mut run = |method: StoppingMethod, probe_every: usize| -> Result<(f64, f64, f64)> {
        let mut ds = data::build_lm(&cfg, &bundle.manifest)?;
        let mut opts = TrainerOptions::from_config(&cfg, method);
        opts.total_steps = steps;
        opts.probe_every = probe_every;
        opts.final_validation = false;
        // keep GradES from terminating early: measure pure overhead
        let mut c2 = cfg.clone();
        c2.grades.tau = 0.0;
        let o = trainer::run(&bundle, &c2, &opts, || ds.train.next_batch(), &ds.val)?;
        Ok((o.wall_secs, o.monitor_secs, o.validation_secs))
    };

    let (no_probe, _, _) = run(StoppingMethod::None, usize::MAX)?;
    let (with_monitor, monitor_secs, _) = run(StoppingMethod::GradEs, 1)?;
    let (with_es, _, val_secs) = run(StoppingMethod::ClassicEs, usize::MAX)?;

    println!("## bench_monitor_overhead ({config}, {steps} steps)\n");
    println!("baseline (no probe)        {no_probe:>8.3}s");
    println!(
        "GradES monitor every step  {with_monitor:>8.3}s  (+{:.2}% — paper §7 reports ~3%; probe itself {monitor_secs:.3}s)",
        100.0 * (with_monitor - no_probe) / no_probe
    );
    println!(
        "classic ES (5% validation) {with_es:>8.3}s  (+{:.2}% — validation passes {val_secs:.3}s)",
        100.0 * (with_es - no_probe) / no_probe
    );
    Ok(())
}
