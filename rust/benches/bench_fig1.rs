//! Scaled-down Figures 1 & 4a — `cargo bench` twin of `grades repro fig1`.

use anyhow::Result;
use grades::exp::{fig1, ExpOptions};

fn main() -> Result<()> {
    let mut opts = ExpOptions::quick(80, 8);
    opts.out_dir = grades::config::repo_root().join("results").join("bench");
    fig1::run(&opts, "lm-tiny-fp", 1)
}
