//! Scaled-down Figures 1 & 4a — `cargo bench` twin of `grades repro fig1`.

use anyhow::Result;
use grades::exp::{fig1, ExpOptions};
use grades::runtime::artifact::Client;

fn main() -> Result<()> {
    let client = Client::cpu()?;
    let mut opts = ExpOptions::quick(80, 8);
    opts.out_dir = grades::config::repo_root().join("results").join("bench");
    fig1::run(&client, &opts, "lm-tiny-fp", 1)
}
