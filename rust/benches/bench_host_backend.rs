//! Host-backend performance + fidelity: pure-Rust steps/sec vs the
//! compiled XLA path, and the cross-backend trajectory divergence the
//! differential tests bound. Emits `BENCH_host_backend.json` for the
//! perf trajectory.
//!
//! Always measures the host engine (no artifacts needed), including a
//! LoRA-engine steps/sec row on the same base shapes. When
//! `artifacts/lm-tiny-fp` exists it also measures the XLA engine, runs
//! the same GradES trajectory from shared initial parameters on both,
//! reports per-step loss divergence — and **fails** (non-zero exit) if
//! the per-matrix freeze steps disagree, so CI catches a physics drift
//! between the engines, not just a slowdown.
//!
//! Alongside the engine rows it measures two kernel-layer microbenches
//! — an attention-bound pass (fused attention fwd+bwd) and an MLP-bound
//! pass (gate/up/down matmuls + SwiGLU fwd+bwd) — so regressions in
//! either kernel family show up even when full-step timing hides them.
//!
//! `--quick` shortens the measured loops (CI smoke mode). `--gate`
//! additionally compares every `*_steps_per_sec` number against the
//! committed baseline in `artifacts/bench_baselines/` and fails on a
//! >10% regression (self-skips with a note when no baseline exists —
//! the gate never invents numbers). `--write-baseline` rewrites that
//! committed file with the numbers just measured (gate format), for
//! recording a real CI-class baseline on the gate's own hardware.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{ensure, Result};
use grades::config::{repo_root, RepoConfig};
use grades::coordinator::scheduler::StepPlan;
use grades::coordinator::trainer::{self, StoppingMethod, TrainOutcome, TrainerOptions};
use grades::coordinator::warmstart::BaseCheckpoint;
use grades::data;
use grades::runtime::artifact::{Bundle, Client};
use grades::runtime::backend::Backend;
use grades::runtime::host_backend::HostBackend;
use grades::runtime::host_kernels::{self as kernels, SimdLevel};
use grades::runtime::session::Session;
use grades::util::json::{self, Json};
use grades::util::timer::Timer;

const CONFIG: &str = "lm-tiny-fp";

fn steps_per_sec(backend: &dyn Backend, cfg: &RepoConfig, iters: usize) -> Result<f64> {
    let m = backend.manifest();
    steps_per_sec_plan(backend, cfg, iters, &StepPlan::all_active(m.n_components))
}

fn steps_per_sec_plan(
    backend: &dyn Backend,
    cfg: &RepoConfig,
    iters: usize,
    plan: &StepPlan,
) -> Result<f64> {
    let mut ds = data::build_lm(cfg, backend.manifest())?;
    let batch = ds.train.next_batch();
    let m = backend.manifest();
    let mut ctrl = vec![1f32; m.ctrl_len];
    ctrl[1] = 1e-4;
    for (ci, c) in ctrl[m.ctrl_mask_offset..m.ctrl_mask_offset + m.n_components]
        .iter_mut()
        .enumerate()
    {
        *c = if plan.omits(ci) { 0.0 } else { 1.0 };
    }
    let lowered = backend.lower_plan(plan);
    let mut session = Session::new(backend);
    session.init(1)?;
    for t in 0..3 {
        ctrl[0] = (t + 1) as f32;
        session.train_step(&batch, &ctrl, &lowered)?;
    }
    let t0 = Timer::new();
    for t in 0..iters {
        ctrl[0] = (t + 4) as f32;
        session.train_step(&batch, &ctrl, &lowered)?;
    }
    Ok(iters as f64 / t0.secs())
}

/// One monitored GradES run from shared initial parameters (generous τ
/// after a short grace: deterministic freezing on both engines).
fn grades_run(
    backend: &dyn Backend,
    steps: usize,
    warm: Arc<BaseCheckpoint>,
) -> Result<TrainOutcome> {
    let mut cfg = RepoConfig::by_name(CONFIG)?;
    cfg.grades.alpha = 0.2;
    cfg.grades.tau = 5.0;
    let mut ds = data::build_lm(&cfg, backend.manifest())?;
    let val: Vec<_> = ds.val.iter().take(2).cloned().collect();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    opts.total_steps = steps;
    opts.probe_every = 1;
    opts.warm_start = Some(warm);
    trainer::run(backend, &cfg, &opts, || ds.train.next_batch(), &val)
}

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let gate = std::env::args().any(|a| a == "--gate");
    let write_baseline = std::env::args().any(|a| a == "--write-baseline");
    let iters = if quick { 8 } else { 30 };
    let traj_steps = if quick { 12 } else { 30 };
    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("quick".into(), Json::Bool(quick));

    let cfg = RepoConfig::by_name(CONFIG)?;
    let host = HostBackend::for_config(&cfg)?;
    let host_sps = steps_per_sec(&host, &cfg, iters)?;
    println!("## bench_host_backend ({CONFIG})\n");
    println!("host  backend: {host_sps:8.2} steps/s");
    report.insert("host_steps_per_sec".into(), Json::Num(host_sps));

    // --- host steps/sec trajectory over the freeze progression ---
    // Three plan shapes bracket a GradES run: all components active,
    // attention frozen+omitted, and everything omitted with sweep
    // truncation granted (forward + head backward + masked update — the
    // floor a fully frozen model converges to).
    {
        let m = host.manifest();
        let n = m.n_components;
        let all: Vec<usize> = (0..n).collect();
        let dense = steps_per_sec_plan(&host, &cfg, iters, &StepPlan::all_active(n))?;
        let attn = steps_per_sec_plan(
            &host,
            &cfg,
            iters,
            &StepPlan::omitting(n, &m.components_where(|c| c.group == "attention")),
        )?;
        let opt_only =
            steps_per_sec_plan(&host, &cfg, iters, &StepPlan::omitting(n, &all).with_truncation())?;
        println!("host  trajectory: dense {dense:8.2} | attn-frozen {attn:8.2} | optimizer-only {opt_only:8.2} steps/s");
        report.insert("dense_steps_per_sec".into(), Json::Num(dense));
        report.insert("attn_frozen_steps_per_sec".into(), Json::Num(attn));
        report.insert("optimizer_only_steps_per_sec".into(), Json::Num(opt_only));
    }

    // --- SIMD + threads A/B on the dense step ---
    // In-process comparison via the kernel-layer overrides: the scalar
    // 1-thread floor vs the best SIMD level on 4 workers. Results are
    // bitwise identical by construction; only wall clock moves.
    {
        let n = host.manifest().n_components;
        let dense = StepPlan::all_active(n);
        kernels::set_simd_override(Some(SimdLevel::Scalar));
        kernels::set_thread_override(Some(1));
        let scalar_1t = steps_per_sec_plan(&host, &cfg, iters, &dense)?;
        let level = kernels::best_available();
        kernels::set_simd_override(Some(level));
        kernels::set_thread_override(Some(4));
        let simd_4t = steps_per_sec_plan(&host, &cfg, iters, &dense)?;
        kernels::set_simd_override(None);
        kernels::set_thread_override(None);
        println!(
            "host  dense A/B: scalar/1t {scalar_1t:8.2} | {}/4t {simd_4t:8.2} steps/s ({:.2}x)",
            level.as_str(),
            simd_4t / scalar_1t
        );
        report.insert("scalar_1t_steps_per_sec".into(), Json::Num(scalar_1t));
        report.insert("simd_4t_steps_per_sec".into(), Json::Num(simd_4t));
        report.insert("simd_speedup_vs_scalar_1t".into(), Json::Num(simd_4t / scalar_1t));
        report.insert("simd_level".into(), Json::Str(level.as_str().into()));
    }

    // --- kernel microbenches: attention-bound and MLP-bound rows ---
    // Direct kernel-layer loops (no optimizer, no data pipeline) so the
    // fused-attention and SwiGLU/matmul paths are measured in isolation:
    // one "step" is a full forward + backward through the block. Shapes
    // are larger than lm-tiny so the kernels, not the glue, dominate.
    {
        use grades::runtime::host_arena::{buf_raw, buf_zeroed};
        use grades::util::rng::Rng;
        let mut rng = Rng::new(0xbe7c);
        let mut randv = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.gauss() as f32).collect() };
        let reps = if quick { 20 } else { 200 };

        let (b, t, h, hd) = (2usize, 64usize, 4usize, 16usize);
        let d = h * hd;
        let (q, k, v) = (randv(b * t * d), randv(b * t * d), randv(b * t * d));
        let dctx = randv(b * t * d);
        let attn_pass = || {
            let mut ctx_hm = buf_raw(b * h * t * hd);
            let mut stats = buf_raw(b * h * 2 * t);
            let mut scratch = buf_raw(b * h * t);
            kernels::fused_attention_fwd(
                &q, &k, &v, b, t, h, hd, true, &mut ctx_hm, &mut stats, &mut scratch,
            );
            let mut ctx = buf_raw(b * t * d);
            kernels::gather_heads(&ctx_hm, b, t, h, hd, &mut ctx);
            let mut dq = buf_zeroed(b * h * t * hd);
            let mut dk = buf_zeroed(b * h * t * hd);
            let mut dv = buf_zeroed(b * h * t * hd);
            let mut bscr = buf_raw(b * h * 2 * t);
            kernels::fused_attention_bwd(
                &q, &k, &v, &stats, &dctx, b, t, h, hd, true, &mut dq, &mut dk, &mut dv,
                &mut bscr,
            );
        };
        attn_pass(); // warm the arena pools before timing
        let t0 = Timer::new();
        for _ in 0..reps {
            attn_pass();
        }
        let attn_ps = reps as f64 / t0.secs();

        let (m, f) = (b * t, 4 * d);
        let x = randv(m * d);
        let (wg, wu, wdn) = (randv(d * f), randv(d * f), randv(f * d));
        let dout = randv(m * d);
        let mlp_pass = || {
            let gp = kernels::matmul(&x, &wg, m, d, f);
            let upv = kernels::matmul(&x, &wu, m, d, f);
            let mut sig = buf_raw(m * f);
            let mut act = buf_raw(m * f);
            kernels::swiglu_fwd(&gp, &upv, &mut sig, &mut act);
            let y = kernels::matmul(&act, &wdn, m, f, d);
            let d_act = kernels::matmul_nt(&dout, &wdn, m, d, f);
            let mut dgp = buf_raw(m * f);
            let mut dup = buf_raw(m * f);
            kernels::swiglu_bwd(&d_act, &gp, &upv, &sig, &mut dgp, &mut dup);
            y
        };
        let _warm = mlp_pass();
        let t0 = Timer::new();
        for _ in 0..reps {
            let _ = mlp_pass();
        }
        let mlp_ps = reps as f64 / t0.secs();

        println!(
            "host  microbench: attention-bound {attn_ps:8.2} | mlp-bound {mlp_ps:8.2} passes/s \
             (b={b} t={t} h={h} hd={hd} f={f})"
        );
        report.insert("attention_bound_steps_per_sec".into(), Json::Num(attn_ps));
        report.insert("mlp_bound_steps_per_sec".into(), Json::Num(mlp_ps));
    }

    // --- LoRA engine steps/sec ---
    // Same base shapes, adapter-only optimizer on a frozen base: the
    // step is dominated by the shared forward/backward, but the update
    // and Eq. 1 statistics shrink to the adapter footprint, so the LoRA
    // engine should never fall meaningfully behind the fp dense step.
    {
        let lcfg = RepoConfig::by_name("lm-tiny-lora")?;
        let lora = HostBackend::for_config(&lcfg)?;
        let n = lora.manifest().n_components;
        let lora_sps = steps_per_sec_plan(&lora, &lcfg, iters, &StepPlan::all_active(n))?;
        println!(
            "host  lora engine: {lora_sps:8.2} steps/s ({:.2}x of fp dense)",
            lora_sps / host_sps
        );
        report.insert("lora_steps_per_sec".into(), Json::Num(lora_sps));
        report.insert("lora_over_fp_speedup".into(), Json::Num(lora_sps / host_sps));
    }

    let art = repo_root().join("artifacts").join(CONFIG);
    let loaded = if art.join("manifest.json").exists() {
        // A compile failure (stale artifacts, mismatched XLA extension)
        // downgrades to the host-only report rather than failing the
        // bench — only *divergence between working engines* is fatal.
        match Client::cpu().and_then(|c| Bundle::load(&c, &art)) {
            Ok(b) => Some(b),
            Err(e) => {
                println!("xla   backend: unavailable ({e:#}); host-only report");
                None
            }
        }
    } else {
        println!("xla   backend: skipped (artifacts/{CONFIG} missing — run `make artifacts`)");
        None
    };
    if loaded.is_none() {
        report.insert("xla_available".into(), Json::Bool(false));
    }
    if let Some(bundle) = loaded {
        let xla_sps = steps_per_sec(&bundle, &cfg, iters)?;
        println!("xla   backend: {xla_sps:8.2} steps/s ({:.2}x of host)", xla_sps / host_sps);
        report.insert("xla_available".into(), Json::Bool(true));
        report.insert("xla_steps_per_sec".into(), Json::Num(xla_sps));
        report.insert("xla_over_host_speedup".into(), Json::Num(xla_sps / host_sps));

        // --- trajectory divergence from shared initial parameters ---
        let mut s = Session::new(&bundle);
        s.init(42)?;
        let warm =
            Arc::new(BaseCheckpoint::from_state(&bundle.manifest, &s.state_to_host()?)?);
        let x = grades_run(&bundle, traj_steps, warm.clone())?;
        let h = grades_run(&host, traj_steps, warm)?;
        let mut max_rel = 0f64;
        for (rx, rh) in x.log.records.iter().zip(&h.log.records) {
            let rel = (rx.loss - rh.loss).abs() / rx.loss.abs().max(1e-8);
            max_rel = max_rel.max(rel);
        }
        let ev = |o: &TrainOutcome| -> Vec<(usize, usize)> {
            o.freeze.events.iter().map(|e| (e.step, e.component)).collect()
        };
        let identical = ev(&x) == ev(&h) && x.steps_run == h.steps_run;
        println!(
            "trajectory over {} logged steps: max per-step loss divergence {:.3e}; \
             freeze steps identical: {identical}",
            x.log.records.len().min(h.log.records.len()),
            max_rel,
        );
        report.insert("trajectory_steps".into(), Json::Num(traj_steps as f64));
        report.insert("max_rel_loss_divergence".into(), Json::Num(max_rel));
        report.insert("freeze_steps_identical".into(), Json::Bool(identical));
        ensure!(
            identical,
            "host and XLA backends disagree on freeze steps: xla {:?} vs host {:?}",
            ev(&x),
            ev(&h)
        );
    }

    let out = repo_root().join("BENCH_host_backend.json");
    std::fs::write(&out, json::write(&Json::Obj(report.clone())))?;
    println!("wrote {}", out.display());

    // --- record a real baseline in the gate's format ---
    if write_baseline {
        let base_path = repo_root()
            .join("artifacts")
            .join("bench_baselines")
            .join("BENCH_host_backend.json");
        let mut base: BTreeMap<String, Json> = BTreeMap::new();
        for (key, val) in &report {
            if key.ends_with("_steps_per_sec") {
                base.insert(key.clone(), val.clone());
            }
        }
        base.insert(
            "note".into(),
            Json::Str(
                "Recorded by `bench_host_backend --write-baseline`: raw measured steps/sec \
                 on the recording host. The --gate check fails on a >10% regression against \
                 these numbers, so re-record on the machine the gate runs on."
                    .into(),
            ),
        );
        if let Some(level) = report.get("simd_level") {
            base.insert("simd_level".into(), level.clone());
        }
        base.insert("quick".into(), Json::Bool(quick));
        std::fs::create_dir_all(base_path.parent().unwrap())?;
        std::fs::write(&base_path, json::write(&Json::Obj(base)))?;
        println!("wrote baseline {}", base_path.display());
    }

    // --- regression gate against the committed baseline ---
    if gate {
        let base_path = repo_root().join("artifacts").join("bench_baselines").join(
            "BENCH_host_backend.json",
        );
        if !base_path.exists() {
            println!(
                "gate: no committed baseline at {} — skipping (commit a known-good \
                 BENCH_host_backend.json there to arm the gate)",
                base_path.display()
            );
            return Ok(());
        }
        let baseline = json::parse(&std::fs::read_to_string(&base_path)?)?;
        let Json::Obj(base) = baseline else {
            anyhow::bail!("gate: baseline {} is not a JSON object", base_path.display());
        };
        let mut checked = 0usize;
        for (key, bval) in &base {
            if !key.ends_with("_steps_per_sec") {
                continue;
            }
            let Some(cur) = report.get(key) else { continue };
            let (b, c) = (bval.as_f64()?, cur.as_f64()?);
            checked += 1;
            println!("gate: {key}: {c:8.2} vs baseline {b:8.2} ({:+.1}%)", (c / b - 1.0) * 100.0);
            ensure!(
                c >= 0.9 * b,
                "gate: {key} regressed >10%: {c:.2} steps/s vs baseline {b:.2}"
            );
        }
        println!("gate: {checked} steps/sec gauges within 10% of baseline");
    }
    Ok(())
}
