//! Coordinator/worker runtime overhead, A/B against the in-process pool:
//! the same deterministic mock grid (engine-free, fixed per-job cost) is
//! executed with `--jobs 2` in-process and with `--workers 2` worker
//! processes, then once more with a SIGKILLed worker to price recovery.
//! Asserts the three runs produce identical table cells and emits
//! `BENCH_coordinator.json`.
//!
//! `--quick` shrinks the grid (CI smoke mode).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Result};
use grades::config::repo_root;
use grades::coordinator::trainer::StoppingMethod;
use grades::exp::coordinator::{try_execute, Dispatch, GridOptions, MockOptions};
use grades::exp::fault::MockJobRunner;
use grades::exp::plan::{EvalKind, JobGraph, JobSpec};
use grades::exp::scheduler::{execute, JobStatus, RunReport, SchedulerOptions};
use grades::runtime::backend::BackendChoice;
use grades::util::json::{self, Json};
use grades::util::timer::Timer;

const SETTINGS: &str = "bench-coordinator";

/// `families` pretrains, each warming `per` persisted train jobs.
fn grid_graph(families: usize, per: usize) -> JobGraph {
    let mut g = JobGraph::new();
    for f in 0..families {
        let pre = g.add(JobSpec::pretrain(format!("pre-{f}"), "fake-cfg")).unwrap();
        for i in 0..per {
            g.add(
                JobSpec::train(
                    format!("f{f}/t{i}"),
                    "fake-cfg",
                    StoppingMethod::GradEs,
                    EvalKind::None,
                )
                .warm(pre),
            )
            .unwrap();
        }
    }
    g
}

/// id → final "Avg." accuracy for every job carrying a table result.
fn cells(g: &JobGraph, r: &RunReport) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for (i, s) in r.statuses.iter().enumerate() {
        if let JobStatus::Done { result: Some(res), .. } = s {
            out.insert(g.get(i).id.clone(), res.accuracies.last().unwrap().1);
        }
    }
    out
}

fn in_process(g: &JobGraph, dir: &Path, sleep_ms: u64) -> Result<(RunReport, f64)> {
    let opts = SchedulerOptions {
        jobs: 2,
        manifest_path: Some(dir.join("inproc_manifest.json")),
        settings: SETTINGS.to_string(),
        backend: BackendChoice::Host,
        ..Default::default()
    };
    let mut runner = MockJobRunner::new(SETTINGS, BackendChoice::Host);
    runner.sleep_ms = sleep_ms;
    let t0 = Timer::new();
    let report = execute(g, &opts, &runner)?;
    let secs = t0.secs();
    report.require_ok(g)?;
    Ok((report, secs))
}

fn distributed(
    g: &JobGraph,
    dir: &Path,
    sleep_ms: u64,
    label: &str,
    fault: Option<&str>,
) -> Result<(RunReport, f64)> {
    let opts = SchedulerOptions {
        jobs: 1,
        manifest_path: Some(dir.join(format!("{label}_manifest.json"))),
        settings: SETTINGS.to_string(),
        backend: BackendChoice::Host,
        workers: 2,
        grid: GridOptions {
            worker_cmd: Some(vec![
                env!("CARGO_BIN_EXE_grades").to_string(),
                "worker".to_string(),
            ]),
            lease_ms: 5_000,
            heartbeat_ms: 100,
            fault: fault.map(str::to_string),
            mock: Some(MockOptions { sleep_ms, log: None }),
            ..Default::default()
        },
        ..Default::default()
    };
    let t0 = Timer::new();
    let report = match try_execute(g, &opts)? {
        Dispatch::Ran(r) => r,
        Dispatch::Fallback(why) => bail!("coordinator fell back ({why}) — bench needs workers"),
    };
    let secs = t0.secs();
    report.require_ok(g)?;
    Ok((report, secs))
}

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (families, per, sleep_ms) = if quick { (2, 3, 20) } else { (4, 6, 50) };
    let g = grid_graph(families, per);
    let dir: PathBuf = std::env::temp_dir().join("grades_bench_coordinator");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir)?;

    println!("## bench_coordinator ({} jobs, {sleep_ms}ms each)\n", g.len());
    let (seq_report, inproc_secs) = in_process(&g, &dir, sleep_ms)?;
    println!("in-process pool (--jobs 2):    {inproc_secs:7.3}s");
    let (dist_report, dist_secs) = distributed(&g, &dir, sleep_ms, "dist", None)?;
    println!("worker processes (--workers 2): {dist_secs:7.3}s ({:.2}x)", dist_secs / inproc_secs);
    let (fault_report, fault_secs) =
        distributed(&g, &dir, sleep_ms, "fault", Some("0:sigkill@2"))?;
    println!(
        "…with one worker SIGKILLed:     {fault_secs:7.3}s (+{:.3}s recovery)",
        fault_secs - dist_secs
    );

    let baseline = cells(&g, &seq_report);
    ensure!(baseline == cells(&g, &dist_report), "distributed cells diverge from in-process");
    ensure!(baseline == cells(&g, &fault_report), "post-recovery cells diverge from in-process");
    println!("table cells identical across all three runs: true");

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("quick".into(), Json::Bool(quick));
    report.insert("jobs".into(), Json::Num(g.len() as f64));
    report.insert("mock_job_ms".into(), Json::Num(sleep_ms as f64));
    report.insert("in_process_secs".into(), Json::Num(inproc_secs));
    report.insert("distributed_secs".into(), Json::Num(dist_secs));
    report.insert("distributed_over_in_process".into(), Json::Num(dist_secs / inproc_secs));
    report.insert("sigkill_recovery_secs".into(), Json::Num(fault_secs - dist_secs));
    report.insert("cells_identical".into(), Json::Bool(true));
    let out = repo_root().join("BENCH_coordinator.json");
    std::fs::write(&out, json::write(&Json::Obj(report)))?;
    println!("wrote {}", out.display());
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
