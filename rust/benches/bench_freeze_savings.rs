//! Freeze-aware step planning: the measured speedup curve.
//!
//! Host-only (no artifacts, no PJRT — always runs). Three parts, two of
//! which are hard gates (non-zero exit on failure):
//!
//! 1. **All-active gate** — a GradES run whose plans never omit
//!    anything (τ = 0) must be bitwise-identical to the planner-off
//!    dense path: same per-step losses, same final state. Plan
//!    threading alone must perturb nothing.
//! 2. **Savings curve gate** — run a real GradES trajectory, then
//!    re-measure host steps/sec under each freeze-set plateau the
//!    trajectory actually visited (same state, same batch; only the
//!    mask + plan differ). Steps/sec must rise **strictly** as the
//!    omitted-dW share grows (plateaus closer than 20% of monitored
//!    params are merged so the assert never rides on timer noise).
//! 3. **No-plan vs plan A/B** — the same trajectory with elision off:
//!    identical freeze events (asserted) and the whole-run wall ratio.
//!
//! Freeze timing is data-dependent, so the benched trajectory's τ is
//! picked from a fixed ladder: the value producing the most distinct
//! freeze plateaus wins (the τ=∞ rung deterministically freezes every
//! component at the first post-grace probe, so a curve always exists).
//!
//! Emits `BENCH_freeze_savings.json`. `--quick` shortens the loops.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};
use grades::config::{repo_root, RepoConfig};
use grades::coordinator::scheduler::StepPlan;
use grades::coordinator::trainer::{self, StoppingMethod, TrainOutcome, TrainerOptions};
use grades::data;
use grades::runtime::backend::Backend;
use grades::runtime::host_backend::HostBackend;
use grades::runtime::session::{Batch, Session};
use grades::util::json::{self, Json};
use grades::util::timer::Timer;

const CONFIG: &str = "lm-tiny-fp";

/// τ ladder the benched trajectory is tuned over (most plateaus wins;
/// ties go to the earliest rung). The ∞ rung cannot fail to freeze.
const TAU_LADDER: [f64; 4] = [0.05, 0.5, 5.0, 1e9];

/// One GradES run under τ; `elide` toggles freeze-aware planning.
fn grades_run(
    be: &HostBackend,
    steps: usize,
    tau: f64,
    elide: bool,
) -> Result<(TrainOutcome, Vec<f32>)> {
    let mut cfg = RepoConfig::by_name(CONFIG)?;
    cfg.grades.alpha = 0.25;
    cfg.grades.tau = tau;
    let mut ds = data::build_lm(&cfg, be.manifest())?;
    let val: Vec<_> = ds.val.iter().take(2).cloned().collect();
    let mut opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    opts.total_steps = steps;
    opts.probe_every = 1;
    opts.elide_frozen = elide;
    let trained = trainer::run_and_keep(be, &cfg, &opts, || ds.train.next_batch(), &val)?;
    let state = trained.session.state_to_host()?;
    Ok((trained.outcome, state))
}

/// Cumulative freeze sets after each event step, starting all-active.
fn freeze_plateaus(o: &TrainOutcome) -> Vec<(usize, Vec<usize>)> {
    let mut plateaus: Vec<(usize, Vec<usize>)> = vec![(0, Vec::new())];
    let mut current: Vec<usize> = Vec::new();
    let mut events = o.freeze.events.clone();
    events.sort_by_key(|e| e.step);
    for e in &events {
        if e.frozen {
            current.push(e.component);
        } else {
            current.retain(|&c| c != e.component);
        }
        match plateaus.last_mut() {
            Some(last) if last.0 == e.step => last.1 = current.clone(),
            _ => plateaus.push((e.step, current.clone())),
        }
    }
    plateaus
}

/// [`freeze_plateaus`] decimated for measurement: keep the all-active
/// baseline, then only plateaus ≥20% of monitored dW params beyond the
/// last kept one (so the strict-monotonicity gate measures real work
/// deltas, not timer noise); intermediate plateaus fold forward into
/// the newest set, and sets too close to the baseline are dropped.
fn merged_plateaus(o: &TrainOutcome, comp_params: &[usize]) -> Vec<(usize, Vec<usize>)> {
    let total: usize = comp_params.iter().sum();
    let omitted_of = |set: &[usize]| -> usize { set.iter().map(|&c| comp_params[c]).sum() };
    let mut kept: Vec<(usize, Vec<usize>)> = Vec::new();
    for p in freeze_plateaus(o) {
        match kept.last() {
            None => kept.push(p),
            Some(last) => {
                let gap = omitted_of(&p.1).abs_diff(omitted_of(&last.1));
                if gap * 5 >= total {
                    kept.push(p);
                } else if kept.len() > 1 {
                    *kept.last_mut().unwrap() = p;
                } // else: too close to the all-active baseline — drop
            }
        }
    }
    kept
}

/// Steps/sec under a fixed freeze set: same base state, same batch,
/// mask and plan derived from `frozen`. This replays a plateau of the
/// real trajectory under controlled timing conditions.
fn plateau_steps_per_sec(
    be: &HostBackend,
    base: &[f32],
    batch: &Batch,
    frozen: &[usize],
    iters: usize,
) -> Result<f64> {
    let m = be.manifest();
    let mut ctrl = vec![0f32; m.ctrl_len];
    ctrl[1] = 1e-4;
    ctrl[2] = 1.0;
    for c in ctrl.iter_mut().skip(m.ctrl_mask_offset) {
        *c = 1.0;
    }
    for &c in frozen {
        ctrl[m.ctrl_mask_offset + c] = 0.0;
    }
    let plan = StepPlan::omitting(m.n_components, frozen);
    let mut session = Session::new(be);
    session.state_from_host(base)?;
    for t in 0..2 {
        ctrl[0] = (t + 1) as f32;
        session.train_step(batch, &ctrl, &plan)?;
    }
    let t0 = Timer::new();
    for t in 0..iters {
        ctrl[0] = (t + 3) as f32;
        session.train_step(batch, &ctrl, &plan)?;
    }
    Ok(iters as f64 / t0.secs())
}

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let traj_steps = if quick { 16 } else { 40 };
    let iters = if quick { 10 } else { 25 };
    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("quick".into(), Json::Bool(quick));
    report.insert(
        "host_threads".into(),
        Json::Str(std::env::var("GRADES_HOST_THREADS").unwrap_or_else(|_| "unset".into())),
    );

    let cfg = RepoConfig::by_name(CONFIG)?;
    let be = HostBackend::for_config(&cfg)?;
    let m = be.manifest();
    println!("## bench_freeze_savings ({CONFIG}, host engine)\n");

    // --- gate 1: all-active plan ≡ pre-refactor dense path, bitwise ---
    {
        let steps = if quick { 6 } else { 10 };
        let (dense, dense_state) = grades_run(&be, steps, 0.0, false)?;
        let (planned, planned_state) = grades_run(&be, steps, 0.0, true)?;
        let losses_equal = dense.log.records.len() == planned.log.records.len()
            && dense
                .log
                .records
                .iter()
                .zip(&planned.log.records)
                .all(|(a, b)| a.loss.to_bits() == b.loss.to_bits());
        let state_equal = dense_state.len() == planned_state.len()
            && dense_state.iter().zip(&planned_state).all(|(a, b)| a.to_bits() == b.to_bits());
        println!(
            "all-active gate: losses bitwise {losses_equal}, final state bitwise {state_equal}"
        );
        report.insert("all_active_bitwise".into(), Json::Bool(losses_equal && state_equal));
        ensure!(
            losses_equal && state_equal && planned.plan.elided_steps == 0,
            "an all-active plan changed the trajectory — plan threading is not a no-op"
        );
    }

    // --- pick the τ that yields the richest real trajectory ---
    // "richest" = most *measurable* (merged) plateaus: tiny staggered
    // freezes that would all fold into the baseline don't count, and the
    // τ=∞ rung always yields two (baseline + everything frozen).
    let comp_params: Vec<usize> = m.components.iter().map(|c| c.n_params).collect();
    let mut best: Option<(f64, TrainOutcome, Vec<f32>)> = None;
    for &tau in &TAU_LADDER {
        let (o, state) = grades_run(&be, traj_steps, tau, true)?;
        let n_kept = merged_plateaus(&o, &comp_params).len();
        println!(
            "tau={tau:>7}: {} freeze event(s) over {} step(s), {} measurable plateau(s), {} dW elided",
            o.freeze.events.len(),
            o.steps_run,
            n_kept,
            o.timings.dw_elided,
        );
        let better = match &best {
            None => true,
            Some((_, b, _)) => n_kept > merged_plateaus(b, &comp_params).len(),
        };
        if better {
            best = Some((tau, o, state));
        }
    }
    let (tau, outcome, final_state) = best.expect("ladder is non-empty");
    println!(
        "\nbenched trajectory: tau={tau}, {} steps, stop={:?}",
        outcome.steps_run, outcome.stop_cause
    );
    report.insert("tau".into(), Json::Num(tau));
    report.insert("trajectory_steps".into(), Json::Num(outcome.steps_run as f64));
    ensure!(
        !outcome.freeze.events.is_empty(),
        "benched trajectory froze nothing — even the τ=∞ ladder rung failed"
    );

    // --- the measured curve over the trajectory's plateaus ---
    let mut ds = data::build_lm(&cfg, m)?;
    let batch = ds.train.next_batch();
    let omitted_of = |set: &[usize]| -> usize { set.iter().map(|&c| comp_params[c]).sum() };
    let kept = merged_plateaus(&outcome, &comp_params);

    println!("\n{:>10} {:>9} {:>14} {:>12}", "after_step", "n_frozen", "omitted_params", "steps/s");
    let mut series = Vec::new();
    let mut sps_curve = Vec::new();
    for (step, set) in &kept {
        // best-of-3: the strict-monotonicity gate below must measure the
        // work delta, not a scheduling hiccup on a shared CI runner
        let mut sps = 0f64;
        for _ in 0..3 {
            sps = sps.max(plateau_steps_per_sec(&be, &final_state, &batch, set, iters)?);
        }
        println!("{:>10} {:>9} {:>14} {:>12.2}", step, set.len(), omitted_of(set), sps);
        let mut o = BTreeMap::new();
        o.insert("after_step".to_string(), Json::Num(*step as f64));
        o.insert("n_frozen".to_string(), Json::Num(set.len() as f64));
        o.insert("omitted_params".to_string(), Json::Num(omitted_of(set) as f64));
        o.insert("steps_per_sec".to_string(), Json::Num(sps));
        series.push(Json::Obj(o));
        sps_curve.push(sps);
    }
    report.insert("plateaus".into(), Json::Arr(series));

    let monotone = sps_curve.windows(2).all(|w| w[1] > w[0]);
    println!(
        "\nsavings curve: steps/sec strictly increasing across {} plateau(s): {monotone}",
        sps_curve.len()
    );
    report.insert("steps_per_sec_strictly_increasing".into(), Json::Bool(monotone));

    // --- no-plan vs plan A/B over the same trajectory ---
    let (dense_outcome, _) = grades_run(&be, traj_steps, tau, false)?;
    let ev = |o: &TrainOutcome| -> Vec<(usize, usize, bool)> {
        o.freeze.events.iter().map(|e| (e.step, e.component, e.frozen)).collect()
    };
    ensure!(
        ev(&outcome) == ev(&dense_outcome),
        "plan elision changed the freeze trajectory — soundness violation"
    );
    let speedup = dense_outcome.wall_secs / outcome.wall_secs;
    println!(
        "A/B: plan {:.3}s vs no-plan {:.3}s wall → {:.2}x on the full run ({} dW elided)",
        outcome.wall_secs,
        dense_outcome.wall_secs,
        speedup,
        outcome.timings.dw_elided,
    );
    report.insert("plan_wall_secs".into(), Json::Num(outcome.wall_secs));
    report.insert("noplan_wall_secs".into(), Json::Num(dense_outcome.wall_secs));
    report.insert("plan_over_noplan_speedup".into(), Json::Num(speedup));
    report.insert("dw_elided".into(), Json::Num(outcome.timings.dw_elided as f64));
    report.insert(
        "flops_theoretical_savings".into(),
        Json::Num(outcome.flops.theoretical_savings()),
    );
    report
        .insert("flops_realized_savings".into(), Json::Num(outcome.flops.realized_savings()));

    let out = repo_root().join("BENCH_freeze_savings.json");
    std::fs::write(&out, json::write(&Json::Obj(report)))?;
    println!("wrote {}", out.display());

    ensure!(
        monotone && sps_curve.len() >= 2,
        "host steps/sec did not rise strictly after freeze events — per-matrix \
         elision is not paying for itself"
    );
    Ok(())
}
