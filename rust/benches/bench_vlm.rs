//! Scaled-down Tables 2/3/5 + Figure 4b — `cargo bench` twin of
//! `grades repro vlm`.

use anyhow::Result;
use grades::exp::{vlm, ExpOptions};

fn main() -> Result<()> {
    let mut opts = ExpOptions::quick(60, 8);
    opts.out_dir = grades::config::repo_root().join("results").join("bench");
    opts.verbose = true;
    // a bench must measure real runs, never resume cells from a prior one
    opts.resume = false;
    vlm::run(&opts)
}
