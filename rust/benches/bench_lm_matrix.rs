//! Scaled-down Tables 1 & 4 + Figure 3 (tiny scale, short budget) — the
//! `cargo bench` twin of `grades repro lm`.

use anyhow::Result;
use grades::exp::{lm_matrix, ExpOptions};

fn main() -> Result<()> {
    let mut opts = ExpOptions::quick(80, 12);
    opts.out_dir = grades::config::repo_root().join("results").join("bench");
    opts.verbose = true;
    // a bench must measure real runs, never resume cells from a prior one
    opts.resume = false;
    let scales = [("lm-tiny", "lm-tiny-fp", "lm-tiny-lora")];
    lm_matrix::run(&opts, &scales)?;
    Ok(())
}
