//! Blocked vs overlapped evaluation: the classic-ES validation tax, and
//! how much of it the async-eval runtime claws back.
//!
//! Two A/Bs, both emitting into `BENCH_async_eval.json`:
//!
//! 1. **Trainer-level** — for each stopping method, one run with the
//!    blocked baseline (every check is a full synchronous pass) and one
//!    with chunked background validation (`--async-eval` semantics:
//!    chunk 1, unbounded staleness). Wall time, validation seconds,
//!    steps, final val loss and benchmark accuracy per mode. The
//!    headline number is classic-ES's wall-time delta: base and grades
//!    run no validation checks, so their delta is noise by construction.
//!    Also asserts the `--staleness 0` contract: a k = 0 run's val-point
//!    series and step count are bitwise-identical to the blocked run.
//! 2. **Scheduler-level** — a two-cell graph run twice: scoring fused
//!    into the train jobs (PR-2 shape) vs split into standalone eval
//!    jobs that receive the final weights as host payloads and share the
//!    worker pool (`JobKind::Eval`).
//!
//! Needs artifacts (`make artifacts`), like every bench.

use std::collections::BTreeMap;

use anyhow::Result;
use grades::config::{repo_root, RepoConfig};
use grades::coordinator::trainer::{self, StoppingMethod, TrainerOptions, TrainOutcome};
use grades::data;
use grades::eval::harness;
use grades::exp::plan::{EvalKind, JobGraph, JobSpec};
use grades::exp::scheduler::{execute, DeviceRunner, SchedulerOptions};
use grades::exp::ExpOptions;
use grades::runtime::artifact::{Bundle, Client};
use grades::runtime::async_eval::AsyncEvalOptions;
use grades::runtime::pipeline::Prefetcher;
use grades::util::json::Json;

const CONFIG: &str = "lm-tiny-fp";
const STEPS: usize = 120;

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// One full training run + benchmark scoring under the given eval mode.
fn run_once(
    bundle: &Bundle,
    cfg: &RepoConfig,
    method: StoppingMethod,
    async_eval: AsyncEvalOptions,
) -> Result<(TrainOutcome, f64)> {
    let ds = data::build_lm(cfg, &bundle.manifest)?;
    let mut opts = TrainerOptions::from_config(cfg, method);
    opts.total_steps = STEPS;
    opts.async_eval = async_eval;
    let mut source = Prefetcher::spawn(ds.train, opts.pipeline.prefetch_batches);
    let trained = trainer::run_source_and_keep(bundle, cfg, &opts, &mut source, &ds.val)?;
    let suites = grades::eval::benchmarks::lm_suites(&ds.vocab, 0xbe9c, 24);
    let accs = harness::score_suites(&trained.session, &suites)?;
    let avg = accs.last().map(|a| a.1).unwrap_or(f64::NAN);
    Ok((trained.outcome, avg))
}

fn trainer_ab(client: &Client, report: &mut BTreeMap<String, Json>) -> Result<()> {
    let cfg = RepoConfig::by_name(CONFIG)?;
    let bundle = Bundle::by_name(client, CONFIG)?;
    println!("## bench_async_eval — trainer A/B ({CONFIG}, {STEPS} steps)\n");
    println!(
        "{:<8} {:>12} {:>12} {:>9} {:>10} {:>10} {:>8} {:>8}",
        "method", "blocked(s)", "overlap(s)", "delta", "val_blk(s)", "val_ovl(s)", "acc_blk", "acc_ovl"
    );
    for method in [StoppingMethod::None, StoppingMethod::ClassicEs, StoppingMethod::GradEs] {
        let (blocked, acc_b) =
            run_once(&bundle, &cfg, method, AsyncEvalOptions::synchronous())?;
        let (overlapped, acc_o) =
            run_once(&bundle, &cfg, method, AsyncEvalOptions::overlapped(1, usize::MAX))?;

        // --staleness 0 contract: bitwise-identical to the blocked run.
        let (k0, _) = run_once(&bundle, &cfg, method, AsyncEvalOptions::overlapped(4, 0))?;
        assert_eq!(blocked.steps_run, k0.steps_run, "{method:?}: k=0 steps diverged");
        assert_eq!(
            blocked.final_val_loss.to_bits(),
            k0.final_val_loss.to_bits(),
            "{method:?}: k=0 final val loss diverged"
        );
        assert_eq!(
            blocked.log.val_points.len(),
            k0.log.val_points.len(),
            "{method:?}: k=0 check count diverged"
        );
        for ((s1, v1), (s2, v2)) in blocked.log.val_points.iter().zip(&k0.log.val_points) {
            assert_eq!(s1, s2);
            assert_eq!(v1.to_bits(), v2.to_bits(), "{method:?}: k=0 val series diverged at {s1}");
        }

        let delta = 100.0 * (1.0 - overlapped.wall_secs / blocked.wall_secs);
        println!(
            "{:<8} {:>12.3} {:>12.3} {:>8.1}% {:>10.3} {:>10.3} {:>7.2}% {:>7.2}%",
            method.label(),
            blocked.wall_secs,
            overlapped.wall_secs,
            delta,
            blocked.validation_secs,
            overlapped.validation_secs,
            acc_b,
            acc_o,
        );
        let mut entry = BTreeMap::new();
        entry.insert("blocked_wall_secs".into(), num(blocked.wall_secs));
        entry.insert("overlapped_wall_secs".into(), num(overlapped.wall_secs));
        entry.insert("wall_delta_pct".into(), num(delta));
        entry.insert("blocked_validation_secs".into(), num(blocked.validation_secs));
        entry.insert("overlapped_validation_secs".into(), num(overlapped.validation_secs));
        entry.insert("blocked_steps".into(), num(blocked.steps_run as f64));
        entry.insert("overlapped_steps".into(), num(overlapped.steps_run as f64));
        entry.insert("blocked_final_val_loss".into(), num(blocked.final_val_loss));
        entry.insert("overlapped_final_val_loss".into(), num(overlapped.final_val_loss));
        entry.insert("blocked_avg_acc".into(), num(acc_b));
        entry.insert("overlapped_avg_acc".into(), num(acc_o));
        entry.insert("checks_issued".into(), num(overlapped.async_eval.issued as f64));
        entry.insert("chunk_evals".into(), num(overlapped.async_eval.chunk_evals as f64));
        entry.insert(
            "staleness0_bitwise_identical".into(),
            Json::Bool(true), // the asserts above would have aborted otherwise
        );
        report.insert(format!("trainer/{}", method.label()), Json::Obj(entry));
    }
    println!();
    Ok(())
}

fn scheduler_ab(client: &Client, report: &mut BTreeMap<String, Json>) -> Result<()> {
    let mut opts = ExpOptions::quick(STEPS, 16);
    opts.jobs = 2;
    let sopts = SchedulerOptions {
        jobs: 2,
        manifest_path: None,
        resume: false,
        settings: opts.settings_fingerprint(),
        verbose: false,
    };

    // fused: two train jobs that also score (the PR-2 shape)
    let mut fused = JobGraph::new();
    for m in [StoppingMethod::ClassicEs, StoppingMethod::GradEs] {
        fused.add(
            JobSpec::train(format!("bench/fused/{}", m.label()), CONFIG, m, EvalKind::LmSuites)
                .ephemeral(),
        )?;
    }
    // split: training and scoring as separate pool-scheduled jobs
    let mut split = JobGraph::new();
    for m in [StoppingMethod::ClassicEs, StoppingMethod::GradEs] {
        let t = split.add(
            JobSpec::train(format!("bench/split/{}", m.label()), CONFIG, m, EvalKind::None)
                .ephemeral(),
        )?;
        split.add(JobSpec::score(
            format!("bench/split/{}/eval", m.label()),
            CONFIG,
            EvalKind::LmSuites,
            t,
        ))?;
    }

    let t0 = std::time::Instant::now();
    let runner = DeviceRunner::with_client(client, &opts);
    let rep = execute(&fused, &sopts, &runner)?;
    rep.require_ok(&fused)?;
    let fused_secs = t0.elapsed().as_secs_f64();

    let t1 = std::time::Instant::now();
    let runner = DeviceRunner::with_client(client, &opts);
    let rep = execute(&split, &sopts, &runner)?;
    rep.require_ok(&split)?;
    let split_secs = t1.elapsed().as_secs_f64();

    println!(
        "scheduler A/B: fused train+score {fused_secs:.2}s vs split eval jobs {split_secs:.2}s \
         ({:+.1}%)",
        100.0 * (split_secs / fused_secs - 1.0)
    );
    let mut entry = BTreeMap::new();
    entry.insert("fused_secs".into(), num(fused_secs));
    entry.insert("split_secs".into(), num(split_secs));
    report.insert("scheduler/fused_vs_split".into(), Json::Obj(entry));
    Ok(())
}

fn main() -> Result<()> {
    let client = Client::cpu()?;
    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    trainer_ab(&client, &mut report)?;
    scheduler_ab(&client, &mut report)?;
    let out = repo_root().join("BENCH_async_eval.json");
    std::fs::write(&out, grades::util::json::write(&Json::Obj(report)))?;
    println!("wrote {}", out.display());
    Ok(())
}
