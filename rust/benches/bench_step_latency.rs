//! Runtime hot-path latency: every executable across model scales, plus
//! the attn-frozen variant delta (the variant scheduler's realized FLOPs
//! saving) and the host→device batch-upload overhead.
//!
//! This is the L3 perf baseline recorded in EXPERIMENTS.md §Perf.

use anyhow::Result;
use grades::config::RepoConfig;
use grades::data;
use grades::runtime::artifact::{Bundle, Client};
use grades::runtime::session::Session;
use grades::util::timer::bench;

fn main() -> Result<()> {
    let client = Client::cpu()?;
    println!("## bench_step_latency (ms per call)\n");
    println!(
        "{:<14} {:>10} {:>12} {:>10} {:>10} {:>10} {:>10}",
        "config", "train", "train(attn0)", "probe", "eval", "eval_rows", "init"
    );
    for config in ["lm-tiny-fp", "lm-small-fp", "lm-base-fp", "lm-tiny-lora", "vlm-tiny-fp"] {
        let cfg = RepoConfig::by_name(config)?;
        let bundle = Bundle::by_name(&client, config)?;
        let m = &bundle.manifest;
        let mut session = Session::new(&bundle);
        session.init(1)?;

        let batch = if m.is_vlm() {
            data::build_vlm(&cfg, m)?.train[0].clone()
        } else {
            data::build_lm(&cfg, m)?.train.next_batch()
        };
        let mut ctrl = vec![1f32; m.ctrl_len];
        ctrl[0] = 1.0;
        ctrl[1] = 1e-4;

        let t_full = bench(3, 20, || {
            session.train_step(&batch, &ctrl, false).unwrap();
        });
        let t_frozen = bench(3, 20, || {
            session.train_step(&batch, &ctrl, true).unwrap();
        });
        let t_probe = bench(3, 50, || {
            session.probe().unwrap();
        });
        let t_eval = bench(3, 20, || {
            session.eval_batch(&batch).unwrap();
        });
        let t_rows = bench(3, 20, || {
            session.eval_rows(&batch).unwrap();
        });
        let t_init = bench(1, 5, || {
            let mut s2 = Session::new(&bundle);
            s2.init(2).unwrap();
        });
        println!(
            "{:<14} {:>10.3} {:>12.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
            config,
            t_full.p50 * 1e3,
            t_frozen.p50 * 1e3,
            t_probe.p50 * 1e3,
            t_eval.p50 * 1e3,
            t_rows.p50 * 1e3,
            t_init.p50 * 1e3,
        );
        let saving = 100.0 * (1.0 - t_frozen.p50 / t_full.p50);
        println!(
            "{:<14} attn-frozen variant saves {saving:.1}% of step wallclock; probe = {:.2}% of step",
            "", 100.0 * t_probe.p50 / t_full.p50
        );
    }
    Ok(())
}
