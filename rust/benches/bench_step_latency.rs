//! Runtime hot-path latency: every executable across model scales, the
//! attn-frozen variant delta (the variant scheduler's realized FLOPs
//! saving), and the pipelined-runtime A/B — synchronous vs. prefetched +
//! upload-ahead steps/sec, upload-per-call vs. device-resident validation,
//! sequential vs. parallel artifact compile — with an upload/exec/probe
//! breakdown. Emits machine-readable `BENCH_step_latency.json` for the
//! perf trajectory.
//!
//! This is the L3 perf baseline recorded in EXPERIMENTS.md §Perf.

use std::collections::BTreeMap;

use anyhow::Result;
use grades::config::{repo_root, RepoConfig};
use grades::coordinator::scheduler::StepPlan;
use grades::data;
use grades::runtime::artifact::{Bundle, Client};
use grades::runtime::pipeline::{BatchSource, DeviceBatchCache, FixedCycle, Prefetcher};
use grades::runtime::session::Session;
use grades::util::json::Json;
use grades::util::timer::{bench, Timer};

const STEP_ITERS: usize = 30;
const EVAL_PASSES: usize = 10;

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// Steps/sec for the seed's synchronous loop: batch production + upload
/// on the critical path, one batch at a time.
fn sync_steps_per_sec(
    session: &mut Session,
    source: &mut dyn BatchSource,
    ctrl: &[f32],
) -> Result<f64> {
    let full = StepPlan::all_active(session.manifest().n_components);
    for _ in 0..5 {
        let b = source.next_batch();
        session.train_step(&b, ctrl, &full)?;
    }
    let t = Timer::new();
    for _ in 0..STEP_ITERS {
        let b = source.next_batch();
        session.train_step(&b, ctrl, &full)?;
    }
    Ok(STEP_ITERS as f64 / t.secs())
}

/// Steps/sec for the pipelined loop: batches arrive from a prefetch
/// thread and the next step's buffers are staged while the current step
/// executes (mirrors `trainer::run_source`'s hot path).
fn pipelined_steps_per_sec(
    session: &mut Session,
    source: &mut dyn BatchSource,
    ctrl: &[f32],
) -> Result<f64> {
    let full = StepPlan::all_active(session.manifest().n_components);
    let mut staged = Some(session.upload_batch(&source.next_batch())?);
    for _ in 0..5 {
        let io = staged.take().unwrap();
        session.train_step_uploaded(io, ctrl, &full)?;
        staged = Some(session.upload_batch(&source.next_batch())?);
    }
    let t = Timer::new();
    for _ in 0..STEP_ITERS {
        let io = staged.take().unwrap();
        session.train_step_uploaded(io, ctrl, &full)?;
        staged = Some(session.upload_batch(&source.next_batch())?);
    }
    Ok(STEP_ITERS as f64 / t.secs())
}

fn main() -> Result<()> {
    let client = Client::cpu()?;
    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    println!("## bench_step_latency (ms per call)\n");
    println!(
        "{:<14} {:>10} {:>12} {:>10} {:>10} {:>10} {:>10}",
        "config", "train", "train(attn0)", "probe", "eval", "eval_rows", "init"
    );
    for config in ["lm-tiny-fp", "lm-small-fp", "lm-base-fp", "lm-tiny-lora", "vlm-tiny-fp"] {
        let cfg = RepoConfig::by_name(config)?;
        let dir = repo_root().join("artifacts").join(config);
        // compile A/B first (the bundle we keep comes from the parallel path)
        let seq_secs = Bundle::load_with(&client, &dir, false)?.compile_secs;
        let bundle = Bundle::load_with(&client, &dir, true)?;
        let par_secs = bundle.compile_secs;
        let m = &bundle.manifest;
        let mut session = Session::new(&bundle);
        session.init(1)?;

        let batch = if m.is_vlm() {
            data::build_vlm(&cfg, m)?.train[0].clone()
        } else {
            data::build_lm(&cfg, m)?.train.next_batch()
        };
        let mut ctrl = vec![1f32; m.ctrl_len];
        ctrl[0] = 1.0;
        ctrl[1] = 1e-4;

        let full = StepPlan::all_active(m.n_components);
        let attn = StepPlan::omitting(
            m.n_components,
            &m.components_where(|c| c.group == "attention"),
        );
        let t_full = bench(3, 20, || {
            session.train_step(&batch, &ctrl, &full).unwrap();
        });
        let t_frozen = bench(3, 20, || {
            session.train_step(&batch, &ctrl, &attn).unwrap();
        });
        let t_probe = bench(3, 50, || {
            session.probe().unwrap();
        });
        let t_eval = bench(3, 20, || {
            session.eval_batch(&batch).unwrap();
        });
        let t_rows = bench(3, 20, || {
            session.eval_rows(&batch).unwrap();
        });
        let t_init = bench(1, 5, || {
            let mut s2 = Session::new(&bundle);
            s2.init(2).unwrap();
        });
        println!(
            "{:<14} {:>10.3} {:>12.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
            config,
            t_full.p50 * 1e3,
            t_frozen.p50 * 1e3,
            t_probe.p50 * 1e3,
            t_eval.p50 * 1e3,
            t_rows.p50 * 1e3,
            t_init.p50 * 1e3,
        );
        let saving = 100.0 * (1.0 - t_frozen.p50 / t_full.p50);
        println!(
            "{:<14} attn-frozen variant saves {saving:.1}% of step wallclock; probe = {:.2}% of step",
            "", 100.0 * t_probe.p50 / t_full.p50
        );

        // ---- pipelined vs synchronous steps/sec ----
        let (sync_sps, pipe_sps) = if m.is_vlm() {
            let ds = data::build_vlm(&cfg, m)?;
            let mut sync_src = FixedCycle::new(ds.train.clone());
            let sync = sync_steps_per_sec(&mut session, &mut sync_src, &ctrl)?;
            let mut pre = Prefetcher::spawn(FixedCycle::new(ds.train), 2);
            let pipe = pipelined_steps_per_sec(&mut session, &mut pre, &ctrl)?;
            (sync, pipe)
        } else {
            let ds = data::build_lm(&cfg, m)?;
            let mut sync_src = ds.train;
            let sync = sync_steps_per_sec(&mut session, &mut sync_src, &ctrl)?;
            let pre_src = data::build_lm(&cfg, m)?.train;
            let mut pre = Prefetcher::spawn(pre_src, 2);
            let pipe = pipelined_steps_per_sec(&mut session, &mut pre, &ctrl)?;
            (sync, pipe)
        };

        // ---- validation: upload-per-call vs device-resident ----
        let val = if m.is_vlm() {
            data::build_vlm(&cfg, m)?.val
        } else {
            data::build_lm(&cfg, m)?.val
        };
        let t_uncached = bench(1, EVAL_PASSES, || {
            session.eval_mean_loss(&val).unwrap();
        });
        let cache = DeviceBatchCache::upload(&session, &val)?;
        let t_cached = bench(1, EVAL_PASSES, || {
            session.eval_mean_loss_cached(&cache).unwrap();
        });

        println!(
            "{:<14} steps/sec sync {sync_sps:.2} → pipelined {pipe_sps:.2} ({:+.1}%) | val pass {:.2} → {:.2} ms ({:.2}x) | compile {:.2} → {:.2} s",
            "",
            100.0 * (pipe_sps / sync_sps - 1.0),
            t_uncached.p50 * 1e3,
            t_cached.p50 * 1e3,
            t_uncached.p50 / t_cached.p50,
            seq_secs,
            par_secs,
        );
        let tm = session.timings();
        println!(
            "{:<14} breakdown: upload {:.1} MB / {:.3}s ({} copies, {} staged) | exec {:.2}s | probe {:.2}s | eval {:.2}s\n",
            "",
            tm.upload_bytes as f64 / 1e6,
            tm.upload_secs,
            tm.uploads,
            tm.staged_uploads,
            tm.exec_secs,
            tm.probe_secs,
            tm.eval_secs,
        );

        let mut entry = BTreeMap::new();
        entry.insert("train_ms".into(), num(t_full.p50 * 1e3));
        entry.insert("train_attn_frozen_ms".into(), num(t_frozen.p50 * 1e3));
        entry.insert("probe_ms".into(), num(t_probe.p50 * 1e3));
        entry.insert("eval_ms".into(), num(t_eval.p50 * 1e3));
        entry.insert("eval_rows_ms".into(), num(t_rows.p50 * 1e3));
        entry.insert("init_ms".into(), num(t_init.p50 * 1e3));
        entry.insert("sync_steps_per_sec".into(), num(sync_sps));
        entry.insert("pipelined_steps_per_sec".into(), num(pipe_sps));
        entry.insert("pipeline_speedup".into(), num(pipe_sps / sync_sps));
        entry.insert("val_pass_uncached_ms".into(), num(t_uncached.p50 * 1e3));
        entry.insert("val_pass_cached_ms".into(), num(t_cached.p50 * 1e3));
        entry.insert("val_cache_speedup".into(), num(t_uncached.p50 / t_cached.p50));
        entry.insert("compile_sequential_secs".into(), num(seq_secs));
        entry.insert("compile_parallel_secs".into(), num(par_secs));
        entry.insert("compile_speedup".into(), num(seq_secs / par_secs));
        entry.insert("timings".into(), tm.to_json());
        report.insert(config.to_string(), Json::Obj(entry));
    }

    let out = repo_root().join("BENCH_step_latency.json");
    std::fs::write(&out, grades::util::json::write(&Json::Obj(report)))?;
    println!("wrote {}", out.display());
    Ok(())
}
