//! τ × α ablation sweep (paper Tables 6 & 7 shape) on a chosen config.
//!
//!     cargo run --release --example ablation_sweep [config] [steps]

use anyhow::Result;
use grades::exp::{ablation, ExpOptions};

fn main() -> Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "lm-tiny-fp".to_string());
    let steps: Option<usize> = std::env::args().nth(2).and_then(|s| s.parse().ok());
    let mut opts = ExpOptions::default();
    opts.steps_override = steps;
    opts.questions = 24;
    // backend resolution is per config: compiled artifacts when present,
    // the pure-Rust host engine otherwise (ExpOptions::backend = Auto)
    ablation::run(&opts, &config)
}
