//! End-to-end driver (EXPERIMENTS.md §E2E): the full three-layer system on
//! a real small workload.
//!
//! Phase 1 — pretrain the ~9M-parameter `lm-e2e` decoder-only transformer
//!   from scratch for a few hundred steps on the synthetic grammar corpus,
//!   logging the loss curve to results/e2e_pretrain_loss.csv.
//! Phase 2 — fine-tune the pretrained base on the domain-shifted corpus
//!   under {base, +ES, +GradES}, comparing wall time, FLOPs, val loss and
//!   benchmark accuracy — the paper's Table 1/4 story end to end.
//!
//!     cargo run --release --example finetune_lm [steps]

use std::sync::Arc;

use anyhow::Result;
use grades::config::{repo_root, RepoConfig};
use grades::coordinator::trainer::{self, StoppingMethod, TrainerOptions};
use grades::coordinator::warmstart;
use grades::data;
use grades::eval::{benchmarks, harness};
use grades::report::table::Table;
use grades::runtime::artifact::{Bundle, Client};

fn main() -> Result<()> {
    let config = "lm-e2e";
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let cfg = RepoConfig::by_name(config)?;
    let client = Client::cpu()?;
    let bundle = Bundle::by_name(&client, config)?;
    let m = &bundle.manifest;
    let total = if steps > 0 { steps } else { cfg.run.total_steps };
    println!(
        "e2e model: {} params ({} layers x d{} , vocab {}), batch {}x{}",
        m.n_params_total,
        m.components.len() / 7,
        m.flops.head_per_token as usize / (2 * m.vocab_size),
        m.vocab_size,
        m.batch_size,
        m.seq_len
    );

    // ---- Phase 1: pretrain from scratch, log the loss curve ----
    println!("\n[phase 1] pretraining {total} steps on the synthetic corpus…");
    let mut pre_ds = data::build_lm_pretrain(&cfg, m)?;
    let mut popts = TrainerOptions::from_config(&cfg, StoppingMethod::None);
    popts.total_steps = total;
    let pre = trainer::run_and_keep(&bundle, &cfg, &popts, || pre_ds.train.next_batch(), &pre_ds.val)?;
    let out_dir = repo_root().join("results");
    pre.outcome.log.write_loss_csv(&out_dir.join("e2e_pretrain_loss.csv"))?;
    let first = pre.outcome.log.records.first().map(|r| r.loss).unwrap_or(f64::NAN);
    println!(
        "[phase 1] loss {first:.3} -> {:.3} in {:.1}s ({:.0} tok/s); val loss {:.3}; curve -> results/e2e_pretrain_loss.csv",
        pre.outcome.log.final_train_loss(),
        pre.outcome.wall_secs,
        (pre.outcome.steps_run * m.batch_size * m.seq_len) as f64 / pre.outcome.wall_secs,
        pre.outcome.final_val_loss,
    );
    let ck = Arc::new(warmstart::BaseCheckpoint::from_state(m, &pre.session.state_to_host()?)?);

    // ---- Phase 2: fine-tune under the three stopping methods ----
    println!("\n[phase 2] fine-tuning on the domain-shifted corpus…");
    let suites_seed = 0xbe9c;
    let mut t = Table::new(vec![
        "Method", "Steps", "Time (s)", "Speedup", "FLOPs", "Val loss", "Avg acc (%)",
    ]);
    let mut base_time = f64::NAN;
    for method in [StoppingMethod::None, StoppingMethod::ClassicEs, StoppingMethod::GradEs] {
        let mut ds = data::build_lm(&cfg, m)?;
        let mut opts = TrainerOptions::from_config(&cfg, method);
        opts.total_steps = total;
        opts.warm_start = Some(ck.clone());
        let trained =
            trainer::run_and_keep(&bundle, &cfg, &opts, || ds.train.next_batch(), &ds.val)?;
        let o = &trained.outcome;
        if method == StoppingMethod::None {
            base_time = o.wall_secs;
        }
        let suites = benchmarks::lm_suites(&ds.vocab, suites_seed, 24);
        let accs = harness::score_suites(&trained.session, &suites)?;
        let avg = accs.last().map(|a| a.1).unwrap_or(f64::NAN);
        o.log.write_loss_csv(&out_dir.join(format!("e2e_ft_{}_loss.csv", method.label())))?;
        println!(
            "  {:<8} steps={} wall={:.1}s frozen={}/{} val={:.3} acc={avg:.1}%",
            method.label(),
            o.steps_run,
            o.wall_secs,
            o.freeze.n_frozen(),
            o.freeze.n(),
            o.final_val_loss
        );
        t.row(vec![
            method.label().to_string(),
            o.steps_run.to_string(),
            format!("{:.1}", o.wall_secs),
            format!("{:.2}x", base_time / o.wall_secs),
            format!("{:.2e}", o.flops.total()),
            format!("{:.4}", o.final_val_loss),
            format!("{avg:.2}"),
        ]);
    }
    let rendered = format!("## E2E fine-tuning comparison ({config})\n\n{}", t.render());
    println!("\n{rendered}");
    std::fs::write(out_dir.join("e2e_summary.md"), rendered)?;
    println!("wrote results/e2e_summary.md");
    Ok(())
}
