//! VLM demo: the paper's §6.3 observation — vision and language towers
//! converge at different rates, motivating tower-specific thresholds τ
//! (App. C Table 10).
//!
//!     cargo run --release --example vlm_two_tower

use anyhow::Result;
use grades::config::RepoConfig;
use grades::coordinator::trainer::{self, StoppingMethod, TrainerOptions};
use grades::data;
use grades::eval::{benchmarks, harness};
use grades::report::figures::ascii_chart;
use grades::runtime::artifact::{Bundle, Client};

fn main() -> Result<()> {
    let config = "vlm-tiny-fp";
    let cfg = RepoConfig::by_name(config)?;
    let client = Client::cpu()?;
    let bundle = Bundle::by_name(&client, config)?;
    let m = &bundle.manifest;
    println!(
        "two-tower VLM: {} vision + {} language components, τ_vision={} τ_language={}",
        m.components_where(|c| c.tower == "vision").len(),
        m.components_where(|c| c.tower == "language").len(),
        cfg.grades.tau_vision,
        cfg.grades.tau_language
    );

    let ds = data::build_vlm(&cfg, m)?;
    let batches = ds.train.clone();
    let mut i = 0usize;
    let opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    let trained = trainer::run_and_keep(
        &bundle,
        &cfg,
        &opts,
        move || {
            let b = batches[i % batches.len()].clone();
            i += 1;
            b
        },
        &ds.val,
    )?;
    let o = &trained.outcome;
    println!(
        "\ntrained {} steps in {:.2}s (stop {:?}), caption loss {:.3}",
        o.steps_run,
        o.wall_secs,
        o.stop_cause,
        o.log.final_train_loss()
    );

    // freeze order per tower
    let mut vis_steps = Vec::new();
    let mut lang_steps = Vec::new();
    for e in &o.freeze.events {
        let c = &m.components[e.component];
        if c.tower == "vision" {
            vis_steps.push(e.step);
        } else {
            lang_steps.push(e.step);
        }
    }
    let mean = |v: &[usize]| v.iter().sum::<usize>() as f64 / v.len().max(1) as f64;
    println!(
        "mean freeze step: language {:.0} vs vision {:.0} ({} / {} frozen)",
        mean(&lang_steps),
        mean(&vis_steps),
        lang_steps.len(),
        vis_steps.len()
    );

    // tower grad-norm series
    let vis = m.components_where(|c| c.tower == "vision");
    let lang = m.components_where(|c| c.tower == "language");
    let series = |idxs: &[usize]| -> Vec<(f64, f64)> {
        o.log
            .records
            .iter()
            .map(|r| {
                (
                    r.step as f64,
                    idxs.iter().map(|&i| r.gabs[i] as f64).sum::<f64>() / idxs.len() as f64,
                )
            })
            .collect()
    };
    println!(
        "\n{}",
        ascii_chart(
            "mean |grad|_1 per tower",
            &[("vision", series(&vis)), ("language", series(&lang))],
            70,
            12,
            true
        )
    );

    let suites = benchmarks::vlm_suites(&ds.scene_cfg, &ds.vocab, 0x33, 24);
    println!("VLM benchmarks:");
    for (name, acc) in harness::score_suites(&trained.session, &suites)? {
        println!("  {name:<10} {acc:5.1}%");
    }
    Ok(())
}
