//! Three-layer composition proof: run the artifact whose train step was
//! built with the **Pallas** kernels (interpret mode) instead of the fused
//! XLA ops, and verify the training trajectory matches the XLA-kernel
//! artifact step for step.
//!
//!     cargo run --release --example pallas_kernels

use anyhow::Result;
use grades::config::RepoConfig;
use grades::coordinator::scheduler::StepPlan;
use grades::data;
use grades::runtime::artifact::{Bundle, Client};
use grades::runtime::session::Session;

fn main() -> Result<()> {
    let client = Client::cpu()?;
    let steps = 12;
    let mut losses = Vec::new();
    for config in ["lm-tiny-fp", "lm-tiny-pallas"] {
        let cfg = RepoConfig::by_name(config)?;
        let bundle = Bundle::by_name(&client, config)?;
        let m = &bundle.manifest;
        println!("{config}: kernel_impl={}", m.kernel_impl);
        let mut ds = data::build_lm(&cfg, m)?;
        let mut session = Session::new(&bundle);
        session.init(7)?;
        let mut ctrl = vec![0f32; m.ctrl_len];
        for c in ctrl.iter_mut().skip(m.ctrl_mask_offset) {
            *c = 1.0;
        }
        ctrl[2] = 1.0;
        let mut series = Vec::new();
        let t0 = std::time::Instant::now();
        for t in 1..=steps {
            ctrl[0] = t as f32;
            ctrl[1] = 1e-3;
            let b = ds.train.next_batch();
            session.train_step(&b, &ctrl, &StepPlan::all_active(m.n_components))?;
            let metrics = session.probe()?;
            series.push(metrics[0] as f64 / metrics[1].max(1.0) as f64);
        }
        println!(
            "  {} steps in {:.2}s, loss {:.4} -> {:.4}",
            steps,
            t0.elapsed().as_secs_f64(),
            series[0],
            series.last().unwrap()
        );
        losses.push(series);
    }
    // The two artifacts share model/config/seed; only the kernel
    // implementation differs, so trajectories must agree to float noise.
    let max_dev: f64 = losses[0]
        .iter()
        .zip(&losses[1])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    println!("\nmax |loss_xla - loss_pallas| over {steps} steps = {max_dev:.2e}");
    assert!(max_dev < 1e-3, "kernel implementations diverged");
    println!("pallas kernel path verified against the XLA fast path ✔");
    Ok(())
}
