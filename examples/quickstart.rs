//! Quickstart: fine-tune the tiny LM with GradES and watch components
//! freeze.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Loads the AOT artifacts for `lm-tiny-fp`, trains with the GradES
//! monitor, prints freeze events, and scores the 8 benchmark suites.

use anyhow::Result;
use grades::config::RepoConfig;
use grades::coordinator::trainer::{self, StoppingMethod, TrainerOptions};
use grades::data;
use grades::eval::{benchmarks, harness};
use grades::runtime::artifact::{Bundle, Client};

fn main() -> Result<()> {
    let config = "lm-tiny-fp";
    let cfg = RepoConfig::by_name(config)?;
    let client = Client::cpu()?;
    let bundle = Bundle::by_name(&client, config)?;
    println!(
        "loaded {}: {} params, {} monitored components, state {:.1} MB",
        config,
        bundle.manifest.n_params_total,
        bundle.manifest.n_components,
        bundle.manifest.state_len as f64 * 4.0 / 1e6
    );

    let mut ds = data::build_lm(&cfg, &bundle.manifest)?;
    let opts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    let trained =
        trainer::run_and_keep(&bundle, &cfg, &opts, || ds.train.next_batch(), &ds.val)?;

    let o = &trained.outcome;
    println!(
        "\ntrained {} steps in {:.2}s  (stop: {:?})",
        o.steps_run, o.wall_secs, o.stop_cause
    );
    println!("train loss {:.4}  val loss {:.4}", o.log.final_train_loss(), o.final_val_loss);
    for e in &o.freeze.events {
        println!(
            "  step {:>4}  froze {:<18} (metric {:.3})",
            e.step, bundle.manifest.components[e.component].name, e.metric_value
        );
    }
    if let Some(s) = o.variant_swap_step {
        println!("  step {s:>4}  hot-swapped to the attn-frozen backward graph");
    }

    println!("\nbenchmarks:");
    let suites = benchmarks::lm_suites(&ds.vocab, 0xbe9c, 32);
    for (name, acc) in harness::score_suites(&trained.session, &suites)? {
        println!("  {name:<12} {acc:5.1}%");
    }
    Ok(())
}
